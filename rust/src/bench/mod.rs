//! Micro-benchmark harness (criterion is not available offline).
//!
//! Deliberately criterion-flavoured: warmup, fixed-count measurement,
//! median + MAD (robust to scheduler noise on the single shared core),
//! and one-line reports. `cargo bench` runs the `benches/*.rs` binaries
//! (`harness = false`), each of which drives this module.

use crate::util::time::fmt_secs;
use crate::util::Stopwatch;

/// One benchmark's statistics (nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.median_ns / 1e9
    }

    /// Convert to a machine-readable record; `tokens_per_call` is how
    /// many tokens (or other throughput units) one timed call produced.
    pub fn to_record(&self, tokens_per_call: f64) -> BenchRecord {
        let tps = if self.median_ns > 0.0 {
            tokens_per_call * 1e9 / self.median_ns
        } else {
            0.0
        };
        BenchRecord {
            name: self.name.clone(),
            tokens_per_sec: tps,
            ns_per_call: self.median_ns,
        }
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{} MAD, min {}, n={})",
            self.name,
            fmt_secs(self.median_ns / 1e9),
            fmt_secs(self.mad_ns / 1e9),
            fmt_secs(self.min_ns / 1e9),
            self.iters,
        )
    }
}

/// Run `f` `iters` times after `warmup` runs; returns robust stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut dev: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = dev[dev.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns: median,
        mad_ns: mad,
        mean_ns: mean,
        min_ns: samples[0],
    }
}

/// One machine-readable benchmark entry for the CI artifact files
/// (`BENCH_kernels.json` / `BENCH_speed.json`): the perf-trajectory
/// schema the bench-smoke job uploads on every PR.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub tokens_per_sec: f64,
    pub ns_per_call: f64,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "0.0".to_string()
    }
}

/// Serialize records as a JSON array (no serde in the offline build).
pub fn bench_records_json(records: &[BenchRecord]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"tokens_per_sec\": {}, \"ns_per_call\": {}}}{}\n",
            json_escape(&r.name),
            json_num(r.tokens_per_sec),
            json_num(r.ns_per_call),
            if i + 1 < records.len() { "," } else { "" },
        ));
    }
    s.push_str("]\n");
    s
}

/// Write the records to `path` as JSON (the CI bench-smoke artifact).
pub fn write_bench_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, bench_records_json(records))
}

/// A collection of results printed as a suite.
#[derive(Default)]
pub struct Suite {
    pub title: String,
    pub results: Vec<BenchResult>,
}

impl Suite {
    pub fn new(title: &str) -> Suite {
        println!("\n=== bench suite: {title} ===");
        Suite { title: title.to_string(), results: Vec::new() }
    }

    /// Run + record + print one benchmark.
    pub fn run<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, f: F) -> &BenchResult {
        let r = bench(name, warmup, iters, f);
        println!("{}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Ratio of two recorded results' medians (`a / b`).
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|r| r.name == a)?;
        let fb = self.results.iter().find(|r| r.name == b)?;
        Some(fa.median_ns / fb.median_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("spin", 2, 20, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns);
        assert_eq!(r.iters, 20);
    }

    #[test]
    fn json_records_are_well_formed() {
        let records = vec![
            BenchRecord {
                name: "gemm_lut3 4096x4096 B=8 \"avx2\"".into(),
                tokens_per_sec: 1234.5678,
                ns_per_call: 9.9e6,
            },
            BenchRecord { name: "empty".into(), tokens_per_sec: f64::INFINITY, ns_per_call: 0.0 },
        ];
        let json = bench_records_json(&records);
        assert!(json.starts_with("[\n") && json.ends_with("]\n"), "{json}");
        assert!(json.contains("\\\"avx2\\\""), "quotes escaped: {json}");
        assert!(json.contains("\"tokens_per_sec\": 1234.568"), "{json}");
        assert!(json.contains("\"tokens_per_sec\": 0.0"), "non-finite sanitized: {json}");
        assert_eq!(json.matches('{').count(), 2);
        assert_eq!(json.matches("},").count(), 1, "comma between entries only: {json}");
        assert!(bench_records_json(&[]).contains("[\n]"), "empty array stays valid");
    }

    #[test]
    fn result_to_record_computes_throughput() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median_ns: 2e9,
            mad_ns: 0.0,
            mean_ns: 2e9,
            min_ns: 2e9,
        };
        let rec = r.to_record(8.0);
        assert!((rec.tokens_per_sec - 4.0).abs() < 1e-9);
        assert_eq!(rec.ns_per_call, 2e9);
    }

    #[test]
    fn suite_ratio() {
        let mut s = Suite::new("test");
        s.run("fast", 1, 10, || {
            std::hint::black_box(1 + 1);
        });
        s.run("slow", 1, 10, || {
            let mut v = 0u64;
            for i in 0..20_000 {
                v = v.wrapping_mul(31).wrapping_add(i);
            }
            std::hint::black_box(v);
        });
        let ratio = s.ratio("slow", "fast").unwrap();
        assert!(ratio > 1.0, "slow/fast ratio {ratio}");
    }
}
