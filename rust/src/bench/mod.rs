//! Micro-benchmark harness (criterion is not available offline).
//!
//! Deliberately criterion-flavoured: warmup, fixed-count measurement,
//! median + MAD (robust to scheduler noise on the single shared core),
//! and one-line reports. `cargo bench` runs the `benches/*.rs` binaries
//! (`harness = false`), each of which drives this module.

use crate::util::time::fmt_secs;
use crate::util::Stopwatch;

/// One benchmark's statistics (nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.median_ns / 1e9
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{} MAD, min {}, n={})",
            self.name,
            fmt_secs(self.median_ns / 1e9),
            fmt_secs(self.mad_ns / 1e9),
            fmt_secs(self.min_ns / 1e9),
            self.iters,
        )
    }
}

/// Run `f` `iters` times after `warmup` runs; returns robust stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut dev: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = dev[dev.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns: median,
        mad_ns: mad,
        mean_ns: mean,
        min_ns: samples[0],
    }
}

/// A collection of results printed as a suite.
#[derive(Default)]
pub struct Suite {
    pub title: String,
    pub results: Vec<BenchResult>,
}

impl Suite {
    pub fn new(title: &str) -> Suite {
        println!("\n=== bench suite: {title} ===");
        Suite { title: title.to_string(), results: Vec::new() }
    }

    /// Run + record + print one benchmark.
    pub fn run<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, f: F) -> &BenchResult {
        let r = bench(name, warmup, iters, f);
        println!("{}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Ratio of two recorded results' medians (`a / b`).
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|r| r.name == a)?;
        let fb = self.results.iter().find(|r| r.name == b)?;
        Some(fa.median_ns / fb.median_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("spin", 2, 20, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns);
        assert_eq!(r.iters, 20);
    }

    #[test]
    fn suite_ratio() {
        let mut s = Suite::new("test");
        s.run("fast", 1, 10, || {
            std::hint::black_box(1 + 1);
        });
        s.run("slow", 1, 10, || {
            let mut v = 0u64;
            for i in 0..20_000 {
                v = v.wrapping_mul(31).wrapping_add(i);
            }
            std::hint::black_box(v);
        });
        let ratio = s.ratio("slow", "fast").unwrap();
        assert!(ratio > 1.0, "slow/fast ratio {ratio}");
    }
}
