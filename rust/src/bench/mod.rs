//! Micro-benchmark harness (criterion is not available offline).
//!
//! Deliberately criterion-flavoured: warmup, fixed-count measurement,
//! median + MAD (robust to scheduler noise on the single shared core),
//! and one-line reports. `cargo bench` runs the `benches/*.rs` binaries
//! (`harness = false`), each of which drives this module.

use crate::kernels::{simd, NumericsMode};
use crate::util::time::fmt_secs;
use crate::util::Stopwatch;

/// One benchmark's statistics (nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.median_ns / 1e9
    }

    /// Convert to a machine-readable record; `tokens_per_call` is how
    /// many tokens (or other throughput units) one timed call produced.
    /// Tagged with the detected SIMD tier and `exact` numerics — use
    /// [`BenchResult::to_record_mode`] for `Fast`-tier measurements.
    pub fn to_record(&self, tokens_per_call: f64) -> BenchRecord {
        let tps = if self.median_ns > 0.0 {
            tokens_per_call * 1e9 / self.median_ns
        } else {
            0.0
        };
        BenchRecord::new(self.name.clone(), tps, self.median_ns)
    }

    /// [`BenchResult::to_record`] tagged with the numerics mode the
    /// benched path ran under.
    pub fn to_record_mode(&self, tokens_per_call: f64, mode: NumericsMode) -> BenchRecord {
        self.to_record(tokens_per_call).with_numerics(mode)
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (±{} MAD, min {}, n={})",
            self.name,
            fmt_secs(self.median_ns / 1e9),
            fmt_secs(self.mad_ns / 1e9),
            fmt_secs(self.min_ns / 1e9),
            self.iters,
        )
    }
}

/// Run `f` `iters` times after `warmup` runs; returns robust stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut dev: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = dev[dev.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns: median,
        mad_ns: mad,
        mean_ns: mean,
        min_ns: samples[0],
    }
}

/// One machine-readable benchmark entry for the CI artifact files
/// (`BENCH_kernels.json` / `BENCH_speed.json`): the perf-trajectory
/// schema the bench-smoke job uploads on every PR.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub tokens_per_sec: f64,
    pub ns_per_call: f64,
    /// Detected SIMD tier the process ran under
    /// ([`simd::SimdTier::label`]) — lets the perf trajectory separate
    /// machines by vector capability.
    pub simd_tier: &'static str,
    /// Numerics mode the benched kernels used
    /// ([`NumericsMode::label`]): `exact` or `fast`.
    pub numerics: &'static str,
    /// Draft-token acceptance rate for speculative-serving records
    /// (`serve spec …`), in `[0, 1]`. `None` for every other bench —
    /// the JSON writer omits the key entirely so existing records are
    /// byte-identical.
    pub acceptance_rate: Option<f64>,
    /// Fault-containment counters for serving records
    /// ([`BenchRecord::with_robustness`]). `None` for every other
    /// bench — omitted from the JSON like `acceptance_rate`, so a
    /// non-zero `requests_failed` or `shed_total` in a perf record is
    /// visible in the trajectory instead of silently inflating (a shed
    /// or failed request produces no tokens but still took wall time).
    pub robustness: Option<RobustnessTags>,
}

/// The serving-robustness counters a bench record carries alongside its
/// throughput (mirrors the `faults`/`server` sections of
/// [`crate::coordinator::Metrics::report`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RobustnessTags {
    pub requests_failed: u64,
    pub shed_total: u64,
    pub degraded_ticks: u64,
    pub faults_injected: u64,
    pub events_dropped: u64,
}

impl RobustnessTags {
    /// Snapshot the containment counters of a finished serving run.
    pub fn from_metrics(m: &crate::coordinator::Metrics) -> RobustnessTags {
        RobustnessTags {
            requests_failed: m.requests_failed,
            shed_total: m.shed_total,
            degraded_ticks: m.degraded_ticks,
            faults_injected: m.faults_injected,
            events_dropped: m.events_dropped,
        }
    }
}

impl BenchRecord {
    /// Record tagged with the detected SIMD tier and `exact` numerics
    /// (the default mode; see [`BenchRecord::with_numerics`]).
    pub fn new(name: impl Into<String>, tokens_per_sec: f64, ns_per_call: f64) -> BenchRecord {
        BenchRecord {
            name: name.into(),
            tokens_per_sec,
            ns_per_call,
            simd_tier: simd::tier().label(),
            numerics: NumericsMode::Exact.label(),
            acceptance_rate: None,
            robustness: None,
        }
    }

    /// Tag the record with the numerics mode the benched path ran under.
    pub fn with_numerics(mut self, mode: NumericsMode) -> BenchRecord {
        self.numerics = mode.label();
        self
    }

    /// Tag a speculative-serving record with its draft acceptance rate
    /// (clamped to `[0, 1]`; non-finite values sanitize to 0).
    pub fn with_acceptance(mut self, rate: f64) -> BenchRecord {
        self.acceptance_rate = Some(if rate.is_finite() { rate.clamp(0.0, 1.0) } else { 0.0 });
        self
    }

    /// Tag a serving record with the fault-containment counters of the
    /// engine run that produced it
    /// ([`RobustnessTags::from_metrics`]).
    pub fn with_robustness(mut self, tags: RobustnessTags) -> BenchRecord {
        self.robustness = Some(tags);
        self
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "0.0".to_string()
    }
}

/// Serialize records as a JSON array (no serde in the offline build).
pub fn bench_records_json(records: &[BenchRecord]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        let acceptance = match r.acceptance_rate {
            Some(rate) => format!(", \"acceptance_rate\": {}", json_num(rate)),
            None => String::new(),
        };
        let robustness = match r.robustness {
            Some(t) => format!(
                ", \"requests_failed\": {}, \"shed_total\": {}, \"degraded_ticks\": {}, \
                 \"faults_injected\": {}, \"events_dropped\": {}",
                t.requests_failed,
                t.shed_total,
                t.degraded_ticks,
                t.faults_injected,
                t.events_dropped
            ),
            None => String::new(),
        };
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"tokens_per_sec\": {}, \"ns_per_call\": {}, \
             \"simd_tier\": \"{}\", \"numerics\": \"{}\"{}{}}}{}\n",
            json_escape(&r.name),
            json_num(r.tokens_per_sec),
            json_num(r.ns_per_call),
            json_escape(r.simd_tier),
            json_escape(r.numerics),
            acceptance,
            robustness,
            if i + 1 < records.len() { "," } else { "" },
        ));
    }
    s.push_str("]\n");
    s
}

/// Write the records to `path` as JSON (the CI bench-smoke artifact).
pub fn write_bench_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, bench_records_json(records))
}

/// A collection of results printed as a suite.
#[derive(Default)]
pub struct Suite {
    pub title: String,
    pub results: Vec<BenchResult>,
}

impl Suite {
    pub fn new(title: &str) -> Suite {
        println!("\n=== bench suite: {title} ===");
        Suite { title: title.to_string(), results: Vec::new() }
    }

    /// Run + record + print one benchmark.
    pub fn run<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        f: F,
    ) -> &BenchResult {
        let r = bench(name, warmup, iters, f);
        println!("{}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Ratio of two recorded results' medians (`a / b`).
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|r| r.name == a)?;
        let fb = self.results.iter().find(|r| r.name == b)?;
        Some(fa.median_ns / fb.median_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("spin", 2, 20, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns);
        assert_eq!(r.iters, 20);
    }

    #[test]
    fn json_records_are_well_formed() {
        let records = vec![
            BenchRecord::new("gemm_lut3 4096x4096 B=8 \"avx2\"", 1234.5678, 9.9e6),
            BenchRecord::new("empty", f64::INFINITY, 0.0).with_numerics(NumericsMode::Fast),
        ];
        let json = bench_records_json(&records);
        assert!(json.starts_with("[\n") && json.ends_with("]\n"), "{json}");
        assert!(json.contains("\\\"avx2\\\""), "quotes escaped: {json}");
        assert!(json.contains("\"tokens_per_sec\": 1234.568"), "{json}");
        assert!(json.contains("\"tokens_per_sec\": 0.0"), "non-finite sanitized: {json}");
        assert_eq!(json.matches('{').count(), 2);
        assert_eq!(json.matches("},").count(), 1, "comma between entries only: {json}");
        // every record carries the tier + numerics tags
        assert_eq!(json.matches("\"simd_tier\": ").count(), 2, "{json}");
        assert!(json.contains("\"numerics\": \"exact\""), "{json}");
        assert!(json.contains("\"numerics\": \"fast\""), "{json}");
        // acceptance_rate / robustness are opt-in: absent unless tagged
        assert!(!json.contains("acceptance_rate"), "{json}");
        assert!(!json.contains("requests_failed"), "{json}");
        assert!(bench_records_json(&[]).contains("[\n]"), "empty array stays valid");
    }

    #[test]
    fn robustness_tags_serialize_only_when_tagged() {
        let mut m = crate::coordinator::Metrics::new();
        m.requests_failed = 2;
        m.shed_total = 3;
        m.degraded_ticks = 4;
        m.faults_injected = 5;
        m.events_dropped = 6;
        let tags = RobustnessTags::from_metrics(&m);
        assert_eq!(tags.requests_failed, 2);
        assert_eq!(tags.events_dropped, 6);
        let records = vec![
            BenchRecord::new("serve stream", 100.0, 1e7).with_robustness(tags),
            BenchRecord::new("serve spec", 80.0, 1e7).with_acceptance(0.5).with_robustness(tags),
            BenchRecord::new("gemm_lut3", 50.0, 2e7),
        ];
        let json = bench_records_json(&records);
        assert_eq!(json.matches("\"requests_failed\": ").count(), 2, "{json}");
        assert!(
            json.contains(
                "\"requests_failed\": 2, \"shed_total\": 3, \"degraded_ticks\": 4, \
                 \"faults_injected\": 5, \"events_dropped\": 6"
            ),
            "{json}"
        );
        // both opt-in tags compose on one record, acceptance first
        assert!(json.contains("\"acceptance_rate\": 0.500, \"requests_failed\": 2"), "{json}");
        // the untagged record's object still closes right after numerics
        assert!(json.contains("\"numerics\": \"exact\"}"), "{json}");
    }

    #[test]
    fn acceptance_rate_serializes_only_when_tagged() {
        let records = vec![
            BenchRecord::new("serve spec lut2->lut3", 100.0, 1e7).with_acceptance(0.8125),
            BenchRecord::new("serve stream", 50.0, 2e7),
            BenchRecord::new("nan-guard", 1.0, 1.0).with_acceptance(f64::NAN),
            BenchRecord::new("clamped", 1.0, 1.0).with_acceptance(1.5),
        ];
        let json = bench_records_json(&records);
        assert_eq!(json.matches("\"acceptance_rate\": ").count(), 3, "{json}");
        assert!(json.contains("\"acceptance_rate\": 0.812"), "{json}");
        assert!(json.contains("\"acceptance_rate\": 0.0"), "NaN sanitized: {json}");
        assert!(json.contains("\"acceptance_rate\": 1.000"), "clamped to 1: {json}");
        // the untagged record's object still closes right after numerics
        assert!(json.contains("\"numerics\": \"exact\"},"), "{json}");
    }

    #[test]
    fn record_constructor_tags_tier_and_mode() {
        let r = BenchRecord::new("x", 1.0, 1.0);
        assert_eq!(r.simd_tier, simd::tier().label());
        assert_eq!(r.numerics, "exact");
        assert_eq!(r.with_numerics(NumericsMode::Fast).numerics, "fast");
        let res = BenchResult {
            name: "y".into(),
            iters: 1,
            median_ns: 1e9,
            mad_ns: 0.0,
            mean_ns: 1e9,
            min_ns: 1e9,
        };
        assert_eq!(res.to_record_mode(1.0, NumericsMode::Fast).numerics, "fast");
        assert_eq!(res.to_record(1.0).numerics, "exact");
    }

    #[test]
    fn result_to_record_computes_throughput() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median_ns: 2e9,
            mad_ns: 0.0,
            mean_ns: 2e9,
            min_ns: 2e9,
        };
        let rec = r.to_record(8.0);
        assert!((rec.tokens_per_sec - 4.0).abs() < 1e-9);
        assert_eq!(rec.ns_per_call, 2e9);
    }

    #[test]
    fn suite_ratio() {
        let mut s = Suite::new("test");
        s.run("fast", 1, 10, || {
            std::hint::black_box(1 + 1);
        });
        s.run("slow", 1, 10, || {
            let mut v = 0u64;
            for i in 0..20_000 {
                v = v.wrapping_mul(31).wrapping_add(i);
            }
            std::hint::black_box(v);
        });
        let ratio = s.ratio("slow", "fast").unwrap();
        assert!(ratio > 1.0, "slow/fast ratio {ratio}");
    }
}
