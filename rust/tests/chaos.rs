//! Deterministic chaos harness: inject faults at every serving-path
//! site and prove the containment contract of the fault taxonomy
//! (`coordinator/error.rs`):
//!
//!   1. the engine stays live — `step()` never returns `Err` for a
//!      recoverable fault, only the offending request terminates with
//!      `FinishReason::Failed(reason)`;
//!   2. `check_invariants()` holds after **every** tick, faults or not;
//!   3. every KV block drains back to free once the workload completes
//!      and the prefix cache is cleared — contained failures leak
//!      nothing;
//!   4. requests the faults did not touch stream **bitwise-identical**
//!      tokens to a fault-free run of the same scripted workload (the
//!      two-tier numerics contract makes tokens independent of batch
//!      composition, so killing a co-batched request must not perturb
//!      survivors).
//!
//! Determinism: the injector (`util::fault`) keys only on (seed, point
//! name, per-point call count); the workload script keys only on the
//! tick counter; deadlines are only `ZERO` (always expired) or an hour
//! (never expires). Replays are exact.
//!
//! The `chaos-engine-alive:` / `chaos-blocks-leaked:` lines are what
//! the CI chaos lane greps into its step summary.
//!
//! The injector state is process-global, so every test serializes on
//! `LOCK` and starts with a fresh `fault::install` (which resets the
//! counters and the armed list).
#![cfg(feature = "chaos")]

use gptqt::coordinator::{
    Backend, CpuBackend, Engine, EngineConfig, Event, FailReason, FinishReason, PrefixCacheConfig,
    Request, SpeculativeBackend,
};
use gptqt::eval::speed::{build_variant, SpeedVariant};
use gptqt::model::init::random_weights;
use gptqt::model::{presets, Model};
use gptqt::util::fault;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// The complete registry of injection points. A new `fault::point` in
/// the serving path shows up in `points_seen()` and fails the registry
/// test below until it is added here *and* covered by a containment
/// assertion (see CONTRIBUTING.md).
const EXPECTED_POINTS: [&str; 7] = [
    "engine.forward_tick",
    "engine.forward_panic",
    "engine.spec_tick",
    "engine.spec_rollback",
    "kv_pool.append",
    "kv_pool.append.spec",
    "prefix_cache.import",
];

static LOCK: Mutex<()> = Mutex::new(());

fn locked() -> MutexGuard<'static, ()> {
    match LOCK.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn test_model(seed: u64) -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.vocab = 64;
    cfg.max_seq = 48;
    Model::new(cfg.clone(), random_weights(&cfg, seed))
}

fn plain_backend() -> CpuBackend {
    CpuBackend(build_variant(&test_model(42), SpeedVariant::Full, 9))
}

/// GPTQT's free draft/target pair: the 2-bit binary-coding draft
/// against the dense target (mirrors `tests/speculative.rs`).
fn spec_backend() -> SpeculativeBackend<CpuBackend, CpuBackend> {
    let model = test_model(42);
    let draft = build_variant(&model, SpeedVariant::GptqtLut { bits: 2 }, 11);
    let target = build_variant(&model, SpeedVariant::Full, 11);
    SpeculativeBackend::new(CpuBackend(draft), CpuBackend(target), 3)
}

fn churn_cfg() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        block_size: 8,
        total_blocks: 64,
        max_queue: 256,
        eos_token: u32::MAX, // never sampled: deterministic lengths
        prefill_chunk: 4,
        // only the 16-token shared prompt qualifies for caching, so the
        // pin budget stays bounded under churn
        prefix: PrefixCacheConfig { enabled: true, min_tokens: 14, ..Default::default() },
        ..Default::default()
    }
}

fn spec_cfg() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        block_size: 8,
        total_blocks: 128,
        max_queue: 256,
        eos_token: u32::MAX,
        prefill_chunk: 4,
        ..Default::default()
    }
}

/// All prompts share the small test vocabulary (64 entries).
fn shared_prompt() -> Vec<u32> {
    (0..16).map(|i| 2 + (13 * i) % 59).collect()
}

fn unique_prompt(id: u64, len: usize) -> Vec<u32> {
    (0..len as u32).map(|i| 3 + (5 * id as u32 + 7 * i) % 60).collect()
}

/// One scripted step of the workload, keyed on the tick counter so the
/// fault-free and chaos runs replay the identical schedule.
enum Action {
    Submit(Request),
    Cancel(u64),
    /// Disarm the injector mid-run: requests submitted after this tick
    /// are provably untouched, which keeps the bitwise survivor
    /// comparison non-vacuous under any seed. A no-op in runs that
    /// never installed a schedule.
    Disarm,
}

type Script = BTreeMap<u64, Vec<Action>>;

fn push(script: &mut Script, tick: u64, action: Action) {
    script.entry(tick).or_default().push(action);
}

struct RunResult {
    tokens: BTreeMap<u64, Vec<u32>>,
    finish: BTreeMap<u64, FinishReason>,
    ticks: u64,
}

/// Drive `engine` through `script` for exactly `ticks` ticks, checking
/// liveness and pool invariants after every single step.
fn run_script<B: Backend>(engine: &mut Engine<B>, script: &Script, ticks: u64) -> RunResult {
    let mut streamed: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
    let mut tokens = BTreeMap::new();
    let mut finish = BTreeMap::new();
    for tick in 0..ticks {
        if let Some(actions) = script.get(&tick) {
            for action in actions {
                match action {
                    Action::Submit(req) => {
                        // max_queue is sized so depth-shedding never
                        // fires; semantic rejects would be a script bug
                        engine.submit(req.clone()).unwrap_or_else(|e| {
                            panic!("tick {tick}: scripted submit rejected: {e:?}")
                        });
                    }
                    Action::Cancel(id) => {
                        // may be false if a fault already killed it
                        engine.cancel(*id);
                    }
                    Action::Disarm => fault::uninstall(),
                }
            }
        }
        let events = engine
            .step()
            .unwrap_or_else(|e| panic!("tick {tick}: containment failed, engine died: {e}"));
        engine
            .check_invariants()
            .unwrap_or_else(|e| panic!("tick {tick}: pool invariant broken: {e}"));
        for ev in events {
            match ev {
                Event::Token { id, token, .. } => streamed.entry(id).or_default().push(token),
                Event::Finished(r) => {
                    // the engine itself is lossless: the terminal
                    // response must carry exactly the streamed tokens
                    let s = streamed.remove(&r.id).unwrap_or_default();
                    assert_eq!(s, r.tokens, "request {}: stream/response mismatch", r.id);
                    finish.insert(r.id, r.finish);
                    tokens.insert(r.id, r.tokens);
                }
                _ => {}
            }
        }
    }
    assert!(!engine.has_work(), "workload did not drain within {ticks} ticks");
    RunResult { tokens, finish, ticks }
}

/// After a drained run, every block must be back in the free list once
/// the prefix cache releases its pins.
fn assert_drained<B: Backend>(engine: &mut Engine<B>, what: &str) -> usize {
    engine.clear_prefix_cache();
    let leaked = engine.kv().used_blocks();
    assert_eq!(leaked, 0, "{what}: {leaked} KV blocks leaked");
    leaked
}

/// Mixed plain-backend workload: staggered admissions, chunked
/// prefills, a shared prompt exercising prefix-cache insert/hit/import,
/// scripted cancels, instant and never-firing deadlines, and a golden
/// wave submitted after the scripted disarm.
fn churn_script() -> Script {
    let mut script = Script::new();
    for i in 0..48u64 {
        let t = 2 * i;
        let shared = i % 6 == 0;
        let prompt = if shared { shared_prompt() } else { unique_prompt(i, 9 + (i % 5) as usize) };
        let mut req = Request::new(i, prompt, 4 + (i % 5) as usize);
        if !shared && i % 9 == 4 {
            req = req.with_deadline(Duration::ZERO); // expires before admission
        } else if i % 9 == 7 {
            req = req.with_deadline(Duration::from_secs(3600)); // never fires
        }
        push(&mut script, t, Action::Submit(req));
        if !shared && i % 7 == 5 {
            push(&mut script, t + 3, Action::Cancel(i));
        }
    }
    // second wave: keeps the pool churning after the first drains
    for j in 0..16u64 {
        let id = 200 + j;
        let req = Request::new(id, unique_prompt(id, 8 + (j % 4) as usize), 5 + (j % 3) as usize);
        push(&mut script, 320 + 4 * j, Action::Submit(req));
    }
    push(&mut script, 600, Action::Disarm);
    // golden wave: submitted after the disarm, so no fault can touch it
    for j in 0..8u64 {
        let id = 900 + j;
        let req = Request::new(id, unique_prompt(id, 8), 5);
        push(&mut script, 620 + 2 * j, Action::Submit(req));
    }
    script
}

/// Speculative workload: staggered greedy decodes through the
/// draft/verify backend with cancels and a post-disarm golden wave.
fn spec_script() -> Script {
    let mut script = Script::new();
    for i in 0..24u64 {
        let t = 3 * i;
        let req = Request::new(i, unique_prompt(i, 6 + (i % 6) as usize), 5 + (i % 4) as usize);
        push(&mut script, t, Action::Submit(req));
        if i % 7 == 3 {
            push(&mut script, t + 2, Action::Cancel(i));
        }
    }
    push(&mut script, 150, Action::Disarm);
    for j in 0..6u64 {
        let id = 900 + j;
        let req = Request::new(id, unique_prompt(id, 7), 5);
        push(&mut script, 160 + 2 * j, Action::Submit(req));
    }
    script
}

fn normally_finished(f: Option<&FinishReason>) -> bool {
    matches!(f, Some(FinishReason::Eos) | Some(FinishReason::Length))
}

/// Compare every request that finished normally in BOTH runs; returns
/// how many were compared so callers can prove non-vacuity.
fn assert_survivors_bitwise(base: &RunResult, chaos: &RunResult, what: &str) -> usize {
    let mut compared = 0;
    for (id, fin) in &chaos.finish {
        if normally_finished(Some(fin)) && normally_finished(base.finish.get(id)) {
            assert_eq!(
                chaos.tokens[id], base.tokens[id],
                "{what}: surviving request {id} diverged from the fault-free run"
            );
            compared += 1;
        }
    }
    compared
}

/// Tentpole churn: a 2k-tick seeded schedule over the mixed workload.
/// Three armed faults on always-reached points guarantee injections
/// under any seed; the scripted disarm guarantees golden survivors.
#[test]
fn seeded_churn_stays_live_and_survivors_stream_bitwise_identical() {
    let _g = locked();
    let script = churn_script();
    const TICKS: u64 = 1000;

    // fault-free twin: rate-0 schedule (resets counters + armed list)
    fault::install(0, 0, 1);
    let mut base_engine = Engine::new(plain_backend(), churn_cfg());
    let base = run_script(&mut base_engine, &script, TICKS);
    assert_drained(&mut base_engine, "baseline churn");
    assert_eq!(base_engine.metrics.faults_injected, 0, "rate-0 schedule must not fire");

    // chaos twin: seeded 1/149 schedule over every point, plus three
    // armed faults consumed within the first few ticks
    fault::install(0x5EED_CAFE, 1, 149);
    fault::arm("engine.forward_tick");
    fault::arm("kv_pool.append");
    fault::arm("kv_pool.append");
    let mut engine = Engine::new(plain_backend(), churn_cfg());
    let chaos = run_script(&mut engine, &script, TICKS);
    let leaked = assert_drained(&mut engine, "chaos churn");

    assert!(
        engine.metrics.faults_injected >= 3,
        "the three armed faults alone guarantee injections: {}",
        engine.metrics.faults_injected
    );
    assert!(
        engine.metrics.requests_failed >= 3,
        "each armed fault terminates one distinct request: {}",
        engine.metrics.requests_failed
    );

    let compared = assert_survivors_bitwise(&base, &chaos, "churn");
    assert!(compared >= 8, "the 8 golden requests outlive any schedule: compared {compared}");

    let total_ticks = base.ticks + chaos.ticks;
    assert!(total_ticks >= 2000, "churn must cover 2k+ ticks: {total_ticks}");
    println!("chaos-ticks: {total_ticks}");
    println!("chaos-faults-injected: {}", engine.metrics.faults_injected);
    println!("chaos-survivors-compared: {compared}");
    println!("chaos-engine-alive: ok");
    println!("chaos-blocks-leaked: {leaked}");
    fault::uninstall();
}

/// The same containment contract through the speculative backend:
/// draft/verify rounds, accept-with-rollback on the paged pool, and
/// spec-specific fault sites under a seeded schedule.
#[test]
fn seeded_spec_churn_survives_and_matches_fault_free_tokens() {
    let _g = locked();
    let script = spec_script();
    const TICKS: u64 = 400;

    fault::install(0, 0, 1);
    let mut base_engine = Engine::new(spec_backend(), spec_cfg());
    let base = run_script(&mut base_engine, &script, TICKS);
    assert_drained(&mut base_engine, "baseline spec churn");

    fault::install(0xB0BA_F00D, 1, 149);
    fault::arm("engine.spec_tick");
    let mut engine = Engine::new(spec_backend(), spec_cfg());
    let chaos = run_script(&mut engine, &script, TICKS);
    assert_drained(&mut engine, "chaos spec churn");

    assert!(engine.metrics.faults_injected >= 1, "the armed spec fault must fire");
    let compared = assert_survivors_bitwise(&base, &chaos, "spec churn");
    assert!(compared >= 6, "the 6 golden requests outlive any schedule: compared {compared}");
    fault::uninstall();
}

/// Arm every injection point in turn (rate-0 schedules: only armed
/// faults fire), pin the exact `FailReason` each containment path
/// produces, and prove `EXPECTED_POINTS` is the complete registry —
/// a new `fault::point` in serving code fails the set equality until
/// it is added here with its own containment coverage.
#[test]
fn every_fault_point_fires_is_contained_and_registry_is_complete() {
    let _g = locked();
    let mut seen: BTreeSet<&'static str> = BTreeSet::new();

    // --- run 1: plain backend — forward error, pool refusal, and a
    // prefix-cache import fault on a real cache hit -------------------
    {
        fault::install(7, 0, 1);
        fault::arm("engine.forward_tick");
        fault::arm("kv_pool.append");
        fault::arm("prefix_cache.import");
        let mut script = Script::new();
        // dies on its first forward (the armed tick fault)
        push(&mut script, 0, Action::Submit(Request::new(0, unique_prompt(0, 6), 4)));
        // completes its 4-token prompt in one chunk, then the armed
        // append fault refuses its first sampled token
        push(&mut script, 2, Action::Submit(Request::new(1, unique_prompt(1, 4), 4)));
        // donor: fills the prefix cache at prompt completion
        push(&mut script, 4, Action::Submit(Request::new(2, shared_prompt(), 3)));
        // hits the donor's entry; the armed import fault corrupts the
        // snapshot import and only this request dies
        push(&mut script, 12, Action::Submit(Request::new(3, shared_prompt(), 3)));
        // untouched control
        push(&mut script, 14, Action::Submit(Request::new(4, unique_prompt(4, 5), 4)));
        let mut engine = Engine::new(plain_backend(), churn_cfg());
        let run = run_script(&mut engine, &script, 40);
        assert_eq!(run.finish[&0], FinishReason::Failed(FailReason::Backend));
        assert_eq!(run.finish[&1], FinishReason::Failed(FailReason::PoolExhausted));
        assert_eq!(run.finish[&2], FinishReason::Length);
        assert_eq!(run.finish[&3], FinishReason::Failed(FailReason::CacheImport));
        assert_eq!(run.finish[&4], FinishReason::Length);
        assert_eq!(fault::fired_at("engine.forward_tick"), 1);
        assert_eq!(fault::fired_at("kv_pool.append"), 1);
        assert_eq!(fault::fired_at("prefix_cache.import"), 1);
        assert_eq!(engine.metrics.requests_failed, 3);
        assert_eq!(engine.metrics.faults_injected, 3);
        assert_drained(&mut engine, "registry run 1");
        seen.extend(fault::points_seen());
    }

    // --- run 2: a contained panic latches degraded mode but the
    // engine keeps serving (its own run: the latch would suppress the
    // prefix-cache insertion run 1 depends on) ------------------------
    {
        fault::install(11, 0, 1);
        fault::arm("engine.forward_panic");
        let mut script = Script::new();
        push(&mut script, 0, Action::Submit(Request::new(0, unique_prompt(0, 5), 3)));
        push(&mut script, 2, Action::Submit(Request::new(1, unique_prompt(1, 5), 3)));
        let mut engine = Engine::new(plain_backend(), churn_cfg());
        let run = run_script(&mut engine, &script, 30);
        assert_eq!(run.finish[&0], FinishReason::Failed(FailReason::Panic));
        assert_eq!(run.finish[&1], FinishReason::Length, "degraded engine must keep serving");
        assert!(engine.is_degraded(), "a contained panic latches degraded mode");
        assert!(engine.metrics.degraded_ticks > 0);
        assert_eq!(fault::fired_at("engine.forward_panic"), 1);
        assert_drained(&mut engine, "registry run 2");
        seen.extend(fault::points_seen());
    }

    // --- run 3: speculative backend — round failure, rollback
    // protocol violation, and pool refusal inside accept-with-rollback.
    // Staggered so exactly one sequence occupies each spec round.
    {
        fault::install(13, 0, 1);
        fault::arm("engine.spec_tick");
        fault::arm("engine.spec_rollback");
        fault::arm("kv_pool.append.spec");
        let mut script = Script::new();
        for (i, tick) in [(0u64, 0u64), (1, 3), (2, 6), (3, 9)] {
            push(&mut script, tick, Action::Submit(Request::new(i, unique_prompt(i, 4), 6)));
        }
        let mut engine = Engine::new(spec_backend(), spec_cfg());
        let run = run_script(&mut engine, &script, 40);
        assert_eq!(run.finish[&0], FinishReason::Failed(FailReason::Backend));
        assert_eq!(run.finish[&1], FinishReason::Failed(FailReason::SpecRollback));
        assert_eq!(run.finish[&2], FinishReason::Failed(FailReason::PoolExhausted));
        assert_eq!(run.finish[&3], FinishReason::Length);
        assert_eq!(fault::fired_at("engine.spec_tick"), 1);
        assert_eq!(fault::fired_at("engine.spec_rollback"), 1);
        assert_eq!(fault::fired_at("kv_pool.append.spec"), 1);
        assert_drained(&mut engine, "registry run 3");
        seen.extend(fault::points_seen());
    }

    let expected: BTreeSet<&'static str> = EXPECTED_POINTS.iter().copied().collect();
    assert_eq!(
        seen, expected,
        "injection-point registry drifted: update EXPECTED_POINTS and cover the new site"
    );
    fault::uninstall();
}
