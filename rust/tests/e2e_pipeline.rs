//! End-to-end integration over the whole rust stack (no artifacts
//! needed): corpus → calibration → GPTQT quantization → packed backends
//! → coordinator serving → perplexity ordering.

use gptqt::coordinator::{CpuBackend, Engine, EngineConfig, Request};
use gptqt::data::{CorpusGenerator, Dataset};
use gptqt::eval::ppl::{calib_for, eval_for, eval_ppl, EvalConfig};
use gptqt::model::init::random_weights;
use gptqt::model::quantize::quantize_model;
use gptqt::model::{presets, BackendModel, Model};
use gptqt::quant::{Method, QuantConfig};

fn test_model() -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.vocab = 256;
    cfg.max_seq = 64;
    Model::new(cfg.clone(), random_weights(&cfg, 123))
}

fn small_eval() -> EvalConfig {
    EvalConfig { calib_slices: 4, calib_len: 48, eval_windows: 3, eval_len: 48, seed: 0 }
}

#[test]
fn quantize_then_serve_through_lut_backend() {
    let model = test_model();
    let ecfg = small_eval();
    let calib: Vec<_> = calib_for(&ecfg, Dataset::WikiSyn)
        .into_iter()
        .map(|mut s| {
            for t in s.tokens.iter_mut() {
                *t %= 256;
            }
            s
        })
        .collect();
    let qcfg = QuantConfig { explore_grid: 3, ..QuantConfig::with_bits(3) };
    let qm = quantize_model(&model, &calib, Method::Gptqt, &qcfg, false).unwrap();

    // packed layers drive the engine: true LUT-GEMM serving
    let bm = BackendModel::quantized(&model, qm.layers);
    assert_eq!(bm.backend_label(), "gptqt-lut");
    let dense_bytes = BackendModel::dense(&model).streamed_bytes_per_token();
    assert!(bm.streamed_bytes_per_token() * 4 < dense_bytes);

    let mut engine = Engine::new(
        CpuBackend(bm),
        EngineConfig { max_batch: 3, ..Default::default() },
    );
    let gen = CorpusGenerator::new(Dataset::WikiSyn, 256, 0);
    let stream = gen.generate(512, 3);
    for id in 0..6u64 {
        let prompt: Vec<u32> = stream[(id as usize) * 10..(id as usize) * 10 + 6]
            .iter()
            .map(|&t| t % 256)
            .collect();
        engine.submit(Request::new(id, prompt, 8)).unwrap();
    }
    let out = engine.run_to_completion().unwrap();
    assert_eq!(out.len(), 6);
    engine.check_invariants().unwrap();
    assert!(engine.metrics.generated_tokens >= 6);
}

#[test]
fn quantized_serving_matches_dense_on_dequant_weights() {
    // Serving through packed LUT kernels must produce the same greedy
    // tokens as serving the dequantized weights densely (fusion property
    // at system level).
    let model = test_model();
    let ecfg = small_eval();
    let calib: Vec<_> = calib_for(&ecfg, Dataset::WikiSyn)
        .into_iter()
        .map(|mut s| {
            for t in s.tokens.iter_mut() {
                *t %= 256;
            }
            s
        })
        .collect();
    let qcfg = QuantConfig { explore_grid: 3, ..QuantConfig::with_bits(3) };
    let qm = quantize_model(&model, &calib, Method::Gptqt, &qcfg, false).unwrap();

    let packed_bm = BackendModel::quantized(&model, qm.layers);
    let dense_bm = BackendModel::dense(&qm.model);

    let run = |bm: &BackendModel| {
        let mut cache = gptqt::model::KvCache::new(&model.cfg);
        let mut toks = Vec::new();
        let mut last = 5u32;
        for _ in 0..6 {
            let logits = bm.decode_step(last, &mut cache);
            last = gptqt::coordinator::sampler::argmax(&logits);
            toks.push(last);
        }
        toks
    };
    assert_eq!(run(&packed_bm), run(&dense_bm), "fused vs dense generation diverged");
}

#[test]
fn ppl_ordering_full_vs_quantized() {
    let model = test_model();
    let ecfg = small_eval();
    let map_tokens = |mut s: gptqt::data::TokenSlice| {
        for t in s.tokens.iter_mut() {
            *t %= 256;
        }
        s
    };
    let calib: Vec<_> = calib_for(&ecfg, Dataset::WikiSyn).into_iter().map(map_tokens).collect();
    let windows: Vec<_> = eval_for(&ecfg, Dataset::WikiSyn).into_iter().map(map_tokens).collect();

    let full = eval_ppl(&model, &windows);
    let qcfg2 = QuantConfig { explore_grid: 3, ..QuantConfig::with_bits(2) };
    let gptqt2 = quantize_model(&model, &calib, Method::Gptqt, &qcfg2, false).unwrap();
    let rtn2 = quantize_model(&model, &calib, Method::Rtn, &qcfg2, false).unwrap();
    let (p_t, p_r) = (eval_ppl(&gptqt2.model, &windows), eval_ppl(&rtn2.model, &windows));
    assert!(full.is_finite() && p_t.is_finite() && p_r.is_finite());
    assert!(
        p_t <= p_r * 1.05,
        "2-bit GPTQT ppl {p_t} should not lose to RTN {p_r} (full {full})"
    );
}
