//! Prefix-cache integration: a prefix hit must stream bitwise-identical
//! tokens to a cold serve while provably skipping the matched prefill
//! work; copy-on-write must isolate diverging sequences from the cached
//! blocks; and the pool must survive eviction churn with concurrent
//! cancels, draining back to fully free once the cache is cleared.

use gptqt::coordinator::{CpuBackend, Engine, EngineConfig, PrefixCacheConfig, Request};
use gptqt::eval::speed::{build_variant, SpeedVariant};
use gptqt::model::init::random_weights;
use gptqt::model::{presets, BackendModel, Model};
use std::collections::HashMap;

fn test_model(seed: u64) -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.vocab = 64;
    cfg.max_seq = 48;
    Model::new(cfg.clone(), random_weights(&cfg, seed))
}

fn cfg_with_cache(enabled: bool) -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        block_size: 8,
        total_blocks: 64,
        eos_token: u32::MAX, // deterministic lengths
        prefix: PrefixCacheConfig { enabled, ..Default::default() },
        ..Default::default()
    }
}

fn prompt(id: u64, len: usize) -> Vec<u32> {
    (0..len as u32).map(|i| 3 + (5 * id as u32 + 7 * i) % 60).collect()
}

fn serve(engine: &mut Engine<CpuBackend>, reqs: Vec<Request>) -> HashMap<u64, Vec<u32>> {
    for req in reqs {
        engine.submit(req).unwrap();
    }
    let out = engine.run_to_completion().unwrap();
    engine.check_invariants().unwrap();
    out.into_iter().map(|r| (r.id, r.tokens)).collect()
}

/// Hit streams must be bitwise-equal to cold serves, for both the dense
/// and the packed LUT-GEMM backend, and the hit must skip exactly the
/// matched prefill tokens (visible in the prefill accounting).
#[test]
fn prefix_hit_streams_bitwise_equal_to_cold() {
    let model = test_model(42);
    for variant in [SpeedVariant::Full, SpeedVariant::GptqtLut { bits: 3 }] {
        let plen = 12usize;
        let gen = 6usize;
        // cold reference: cache disabled
        let mut cold_engine =
            Engine::new(CpuBackend(build_variant(&model, variant, 9)), cfg_with_cache(false));
        let cold = serve(&mut cold_engine, vec![Request::new(0, prompt(1, plen), gen)]);

        // cache enabled: first serve fills the cache, second hits it
        let mut engine =
            Engine::new(CpuBackend(build_variant(&model, variant, 9)), cfg_with_cache(true));
        let first = serve(&mut engine, vec![Request::new(0, prompt(1, plen), gen)]);
        let after_first = engine.metrics.prefill_tokens_computed;
        assert_eq!(after_first, plen as u64, "{variant:?}: cold prefill computes every token");
        let second = serve(&mut engine, vec![Request::new(1, prompt(1, plen), gen)]);

        assert_eq!(first[&0], cold[&0], "{variant:?}: cache-filling serve diverged from cold");
        assert_eq!(second[&1], cold[&0], "{variant:?}: prefix-hit stream diverged from cold");
        assert_eq!(engine.metrics.prefix_hits, 1, "{variant:?}");
        // matched is capped at plen - 1 (one token must produce logits),
        // so the hit computes exactly one prompt token
        let matched = engine.metrics.prefix_tokens_reused as usize;
        assert_eq!(matched, plen - 1, "{variant:?}");
        assert_eq!(
            engine.metrics.prefill_tokens_computed - after_first,
            (plen - matched) as u64,
            "{variant:?}: hit prefill must compute exactly the unmatched tail"
        );
    }
}

/// A sequence that shares a prefix mid-block and then diverges must (a)
/// copy the shared tail block rather than write into it, (b) produce
/// the same stream a cold engine produces for its full prompt, and (c)
/// leave the cached entry intact for later exact-match hits.
#[test]
fn cow_divergence_isolates_writers_from_cached_blocks() {
    let model = test_model(43);
    let base = prompt(2, 20); // blocks: [0..8), [8..16), [16..20) partial
    let mut fork = base[..14].to_vec(); // diverges mid-block-1
    fork.extend([61, 62, 60, 59, 58, 57]); // 20 tokens total, last 6 differ

    // cold references for both prompts
    let mut cold =
        Engine::new(CpuBackend(BackendModel::dense(&model)), cfg_with_cache(false));
    let cold_out = serve(
        &mut cold,
        vec![Request::new(0, base.clone(), 5), Request::new(1, fork.clone(), 5)],
    );

    let mut engine =
        Engine::new(CpuBackend(BackendModel::dense(&model)), cfg_with_cache(true));
    let a = serve(&mut engine, vec![Request::new(10, base.clone(), 5)]);
    // the donor itself appends past its pinned prompt blocks, so its
    // first generated token already forces one copy-on-write
    assert!(engine.kv().cow_copies() >= 1, "donor append into pinned tail must CoW");
    let cow_after_donor = engine.kv().cow_copies();

    let b = serve(&mut engine, vec![Request::new(11, fork.clone(), 5)]);
    assert!(
        engine.kv().cow_copies() > cow_after_donor,
        "partial-tail share must copy the shared block on divergence"
    );
    assert_eq!(engine.metrics.prefix_hits, 1, "mid-block fork still hits the cache");
    assert_eq!(engine.metrics.prefix_tokens_reused, 14);

    // exact repeat of the original prompt: the cached entry must be
    // unscathed by the fork's writes
    let c = serve(&mut engine, vec![Request::new(12, base.clone(), 5)]);

    assert_eq!(a[&10], cold_out[&0], "donor stream diverged from cold");
    assert_eq!(b[&11], cold_out[&1], "forked stream diverged from cold");
    assert_eq!(c[&12], cold_out[&0], "post-fork exact hit diverged from cold");
    assert_eq!(engine.metrics.prefix_hits, 2);
}

/// Eviction churn with concurrent cancels: a small pool and entry cap
/// force both LRU and pressure evictions while requests cancel
/// mid-flight; the pool invariants must hold throughout and every block
/// must come home once the cache is cleared.
#[test]
fn eviction_churn_with_cancels_keeps_pool_invariants() {
    let model = test_model(44);
    let total_blocks = 32usize;
    let cfg = EngineConfig {
        max_batch: 4,
        block_size: 4,
        total_blocks,
        eos_token: u32::MAX,
        prefix: PrefixCacheConfig {
            enabled: true,
            max_entries: 3,
            max_blocks: 12,
            min_tokens: 1,
            evict_on_pressure: true,
        },
        ..Default::default()
    };
    let mut engine = Engine::new(CpuBackend(BackendModel::dense(&model)), cfg);

    let mut next_id = 0u64;
    for wave in 0..6u64 {
        let mut ids = Vec::new();
        for fam in 0..3u64 {
            // per-family shared prefix + per-request unique tail: some
            // serves hit, some miss, inserts keep rotating the LRU set
            let mut p = prompt(fam, 10 + 2 * fam as usize);
            p.push(3 + (wave * 7 + fam) as u32 % 60);
            p.push(3 + (wave * 11 + fam) as u32 % 60);
            let id = next_id;
            next_id += 1;
            ids.push(id);
            engine.submit(Request::new(id, p, 6)).unwrap();
        }
        // let prefill start, then cancel one member of the wave while
        // the others keep running
        engine.step().unwrap();
        engine.cancel(ids[wave as usize % 3]);
        engine.run_to_completion().unwrap();
        engine.check_invariants().unwrap();
    }

    assert!(engine.metrics.prefix_insertions >= 3, "churn must publish entries");
    assert!(engine.metrics.prefix_hits >= 1, "repeated family prefixes must hit");
    assert!(
        engine.metrics.prefix_evictions >= 1,
        "entry cap of 3 under 18 rotating prompts must evict"
    );
    assert!(engine.metrics.cancelled_total >= 1);

    // cache still holds pinned blocks; dropping it must drain the pool
    assert!(engine.prefix_cache().len() > 0);
    assert!(engine.kv().free_blocks() < total_blocks);
    engine.clear_prefix_cache();
    engine.check_invariants().unwrap();
    assert_eq!(engine.prefix_cache().len(), 0);
    assert_eq!(
        engine.kv().free_blocks(),
        total_blocks,
        "every block must come home after churn + clear"
    );
}
