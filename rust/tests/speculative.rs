//! End-to-end speculative-decoding parity: greedy output through
//! [`SpeculativeBackend`] must be **token-identical** to target-only
//! decoding, for every draft/target pair the two-step quantization
//! yields and under both numerics tiers.
//!
//! The acceptance rule is argmax-based (accept a drafted token iff it
//! equals the target's argmax at that position, emit the target's
//! correction at the first disagreement), so identity holds by
//! construction — this suite pins it through the full engine: batched
//! scheduling, paged KV with accept-with-rollback, prefix-cache hits,
//! and mid-decode cancellation.
//!
//! The `spec-divergences-total:` / `spec-acceptance-rate:` lines
//! printed at the end are what the CI spec-parity lane greps into the
//! step summary, mirroring the fast-numerics divergence gate.

use gptqt::coordinator::{
    CpuBackend, Engine, EngineConfig, Event, FinishReason, PrefixCacheConfig, Request, SpecConfig,
    SpeculativeBackend, SubmitError,
};
use gptqt::eval::speed::{build_variant, SpeedVariant};
use gptqt::kernels::NumericsMode;
use gptqt::model::init::random_weights;
use gptqt::model::{presets, Model};
use std::collections::HashMap;

fn test_model(seed: u64) -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.vocab = 64;
    cfg.max_seq = 48;
    Model::new(cfg.clone(), random_weights(&cfg, seed))
}

/// The two draft/target pairs GPTQT's two quantization steps yield for
/// free: the 2-bit binary-coding draft against the 3-bit LUT target and
/// against the dense (fp32) target.
const PAIRS: [(SpeedVariant, &str); 2] = [
    (SpeedVariant::GptqtLut { bits: 3 }, "lut2->lut3"),
    (SpeedVariant::Full, "lut2->dense"),
];

fn engine_cfg(mode: NumericsMode) -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        total_blocks: 128,
        block_size: 8,
        eos_token: u32::MAX, // fixed-length outputs: counts comparable
        numerics: mode,
        spec: SpecConfig::default(),
        ..Default::default()
    }
}

/// Greedy-only requests over distinct prompts (batched together, so the
/// comparison covers the batched verify forward too).
fn greedy_requests(n: u64, prompt_len: usize, gen: usize) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let prompt: Vec<u32> = (0..prompt_len as u32)
                .map(|i| 3 + (5 * id as u32 + 7 * i) % 60)
                .collect();
            Request::new(id, prompt, gen)
        })
        .collect()
}

fn target_only_engine(
    model: &Model,
    variant: SpeedVariant,
    cfg: EngineConfig,
) -> Engine<CpuBackend> {
    let bm = build_variant(model, variant, 11);
    Engine::new(CpuBackend(bm), cfg)
}

fn spec_engine(
    model: &Model,
    variant: SpeedVariant,
    k: usize,
    cfg: EngineConfig,
) -> Engine<SpeculativeBackend<CpuBackend, CpuBackend>> {
    let draft = build_variant(model, SpeedVariant::GptqtLut { bits: 2 }, 11);
    let target = build_variant(model, variant, 11);
    Engine::new(SpeculativeBackend::new(CpuBackend(draft), CpuBackend(target), k), cfg)
}

fn run_requests<B: gptqt::coordinator::Backend>(
    engine: &mut Engine<B>,
    reqs: Vec<Request>,
) -> HashMap<u64, Vec<u32>> {
    for r in reqs {
        engine.submit(r).unwrap();
    }
    let out = engine.run_to_completion().unwrap();
    engine.check_invariants().unwrap();
    out.into_iter().map(|r| (r.id, r.tokens)).collect()
}

/// Positionwise token mismatches between the two runs' outputs.
fn count_divergences(base: &HashMap<u64, Vec<u32>>, spec: &HashMap<u64, Vec<u32>>) -> u64 {
    assert_eq!(base.len(), spec.len());
    let mut n = 0u64;
    for (id, b) in base {
        let s = &spec[id];
        assert_eq!(b.len(), s.len(), "req {id}: lengths must match (EOS disabled)");
        n += b.iter().zip(s).filter(|(a, c)| a != c).count() as u64;
    }
    n
}

#[test]
fn speculative_greedy_is_token_identical_across_pairs() {
    let model = test_model(5);
    let mut total = 0u64;
    let mut drafted = 0u64;
    let mut accepted = 0u64;
    let mut lines = Vec::new();
    for (variant, pair) in PAIRS {
        for mode in [NumericsMode::Exact, NumericsMode::Fast] {
            let mut base = target_only_engine(&model, variant, engine_cfg(mode));
            let baseline = run_requests(&mut base, greedy_requests(4, 6, 12));
            let mut eng = spec_engine(&model, variant, 4, engine_cfg(mode));
            let spec = run_requests(&mut eng, greedy_requests(4, 6, 12));
            let n = count_divergences(&baseline, &spec);
            total += n;
            assert_eq!(n, 0, "{pair} {}: speculative greedy diverged", mode.label());
            let m = &eng.metrics;
            assert!(m.spec_ticks > 0, "{pair}: speculation never engaged");
            assert!(m.spec_drafted_total > 0, "{pair}: nothing drafted");
            assert_eq!(
                m.spec_accepted_total + m.spec_rolled_back_total,
                m.spec_drafted_total,
                "{pair}: every drafted token is accepted or rolled back"
            );
            assert_eq!(eng.kv().used_blocks(), 0, "{pair}: rollback leaked blocks");
            drafted += m.spec_drafted_total;
            accepted += m.spec_accepted_total;
            lines.push(format!(
                "spec-pair: {pair} {} accept_rate={:.3}",
                mode.label(),
                m.spec_acceptance_rate()
            ));
        }
    }
    for line in &lines {
        println!("{line}");
    }
    // the CI spec-parity lane greps these two into the step summary
    println!("spec-acceptance-rate: {:.3}", accepted as f64 / drafted.max(1) as f64);
    println!("spec-divergences-total: {total}");
}

#[test]
fn speculative_identity_holds_through_prefix_cache_hits() {
    // The same prompt served twice with the prefix cache on: the second
    // request adopts shared KV blocks, so speculative rollback now runs
    // against refcounted state. Output must still match a target-only
    // engine with the identical cache configuration.
    let model = test_model(9);
    let cached = || {
        let mut cfg = engine_cfg(NumericsMode::Exact);
        cfg.prefix = PrefixCacheConfig { enabled: true, ..Default::default() };
        cfg
    };
    let repeat = |tag: u64| {
        let prompt: Vec<u32> = (0..16u32).map(|i| 3 + (11 * i) % 60).collect();
        Request::new(tag, prompt, 8)
    };
    for (variant, pair) in PAIRS {
        let mut base = target_only_engine(&model, variant, cached());
        let mut eng = spec_engine(&model, variant, 4, cached());
        for tag in 0..2u64 {
            let b = run_requests(&mut base, vec![repeat(tag)]);
            let s = run_requests(&mut eng, vec![repeat(tag)]);
            assert_eq!(count_divergences(&b, &s), 0, "{pair} request {tag}");
        }
        assert!(eng.metrics.prefix_hits >= 1, "{pair}: second request must hit the cache");
        eng.clear_prefix_cache();
        assert_eq!(eng.kv().used_blocks(), 0, "{pair}: unpinned pool must drain fully");
    }
}

#[test]
fn cancelled_spec_request_emits_one_terminal_and_blocks_resubmit_until_drain() {
    // Regression: a speculative request cancelled between rounds must
    // emit exactly one terminal event; its id stays reserved
    // (DuplicateId) until that event drains, then resubmits cleanly.
    let model = test_model(7);
    let mut e = spec_engine(
        &model,
        SpeedVariant::GptqtLut { bits: 3 },
        4,
        engine_cfg(NumericsMode::Exact),
    );
    e.submit(Request::new(1, vec![3, 4, 5, 6], 20)).unwrap();
    e.step().unwrap(); // prefill: first token via the normal path
    e.step().unwrap(); // a full draft/verify/rollback round
    assert!(e.metrics.spec_ticks >= 1, "second tick must speculate");
    assert!(e.cancel(1));
    // terminal event still pending: the id is not reusable yet
    assert_eq!(
        e.submit(Request::new(1, vec![3, 4, 5, 6], 4)),
        Err(SubmitError::DuplicateId)
    );
    let evs = e.step().unwrap(); // drains the pending Finished(Cancelled)
    let terminals: Vec<_> = evs
        .iter()
        .filter(|ev| matches!(ev, Event::Finished(r) if r.id == 1))
        .collect();
    assert_eq!(terminals.len(), 1, "exactly one terminal event for the cancelled id");
    match terminals[0] {
        Event::Finished(r) => assert_eq!(r.finish, FinishReason::Cancelled),
        _ => unreachable!(),
    }
    e.submit(Request::new(1, vec![3, 4, 5, 6], 4)).unwrap();
    let out = e.run_to_completion().unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].finish, FinishReason::Length);
    assert_eq!(out[0].tokens.len(), 4);
    assert_eq!(e.metrics.cancelled_total, 1);
    e.check_invariants().unwrap();
    assert_eq!(e.kv().used_blocks(), 0, "cancelled + finished: pool fully drained");
}
