//! End-to-end greedy-decode divergence between the numerics tiers.
//!
//! The `Fast` tier is allowed to perturb logits within the tolerance
//! contract (`numerics_tolerance.rs`), but the serving-level promise is
//! stronger: on the shipped models, **greedy decode under `Fast` emits
//! the same tokens as `Exact`** — argmax gaps dwarf the kernel error.
//! This suite runs the full engine (batched scheduling, paged KV) in
//! both modes over every weight format, counts positionwise token
//! divergences, surfaces the count through
//! [`Metrics::record_greedy_divergences`], and asserts it is zero.
//!
//! The `greedy-divergences-total:` line printed at the end is what the
//! CI fast-numerics leg greps into the step summary.

use gptqt::coordinator::{CpuBackend, Engine, EngineConfig, Metrics, Request};
use gptqt::eval::speed::{build_variant, SpeedVariant};
use gptqt::kernels::NumericsMode;
use gptqt::model::init::random_weights;
use gptqt::model::{presets, Model};
use std::collections::HashMap;

fn test_model(seed: u64) -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.vocab = 64;
    cfg.max_seq = 48;
    Model::new(cfg.clone(), random_weights(&cfg, seed))
}

/// Greedy-only requests over distinct prompts (batched together, so the
/// comparison covers the gemm + threaded-attention paths too).
fn greedy_requests(n: u64, prompt_len: usize, gen: usize) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let prompt: Vec<u32> = (0..prompt_len as u32)
                .map(|i| 3 + (5 * id as u32 + 7 * i) % 60)
                .collect();
            Request::new(id, prompt, gen)
        })
        .collect()
}

/// Run the engine to completion under `mode`; returns id → tokens.
fn decode_tokens(
    model: &Model,
    variant: SpeedVariant,
    mode: NumericsMode,
) -> HashMap<u64, Vec<u32>> {
    let bm = build_variant(model, variant, 11);
    let mut engine = Engine::new(
        CpuBackend(bm),
        EngineConfig {
            max_batch: 4,
            total_blocks: 128,
            block_size: 8,
            eos_token: u32::MAX, // fixed-length outputs: counts comparable
            numerics: mode,
            ..Default::default()
        },
    );
    assert_eq!(engine.metrics.numerics_label, mode.label());
    for r in greedy_requests(4, 6, 10) {
        engine.submit(r).unwrap();
    }
    let out = engine.run_to_completion().unwrap();
    engine.check_invariants().unwrap();
    out.into_iter().map(|r| (r.id, r.tokens)).collect()
}

/// Positionwise token mismatches between the two modes' outputs.
fn count_divergences(exact: &HashMap<u64, Vec<u32>>, fast: &HashMap<u64, Vec<u32>>) -> u64 {
    assert_eq!(exact.len(), fast.len());
    let mut n = 0u64;
    for (id, e) in exact {
        let f = &fast[id];
        assert_eq!(e.len(), f.len(), "req {id}: lengths must match (EOS disabled)");
        n += e.iter().zip(f).filter(|(a, b)| a != b).count() as u64;
    }
    n
}

#[test]
fn fast_greedy_decode_is_token_identical_to_exact() {
    let model = test_model(5);
    let mut metrics = Metrics::new();
    metrics.numerics_label = NumericsMode::Fast.label();
    let mut total = 0u64;
    for variant in [
        SpeedVariant::Full,
        SpeedVariant::GptqInt { bits: 2 },
        SpeedVariant::GptqtLut { bits: 3 },
    ] {
        let exact = decode_tokens(&model, variant, NumericsMode::Exact);
        let fast = decode_tokens(&model, variant, NumericsMode::Fast);
        let n = count_divergences(&exact, &fast);
        metrics.record_greedy_divergences(n);
        total += n;
        assert_eq!(n, 0, "{variant:?}: Fast greedy decode diverged from Exact");
    }
    let report = metrics.report();
    assert!(report.contains("mode=fast"), "{report}");
    assert!(report.contains("greedy_divergences=0"), "{report}");
    // the CI fast-numerics leg greps this into the step summary
    println!("greedy-divergences-total: {total}");
}
