//! Chunk-major forward core parity: chunked prefill must be
//! bit-identical to the sequential single-token decode loop (for dense
//! *and* quantized backends — the kernels pin `gemm == per-item gemv`
//! bitwise and the core preserves per-token fp operation order), the
//! KV cache must hold the same state afterwards, and perplexity routed
//! through `BackendModel` must match the dense `Model` path.

use gptqt::eval::ppl::{eval_for, eval_ppl, eval_ppl_backend, EvalConfig};
use gptqt::model::init::random_weights;
use gptqt::model::{presets, BackendModel, Family, KvCache, Model};
use gptqt::quant::{quantize_layer, Method, QuantConfig};
use gptqt::tensor::Tensor;
use std::collections::HashMap;

fn tiny(family: Family, seed: u64) -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.family = family;
    cfg.vocab = 64;
    cfg.max_seq = 48;
    Model::new(cfg.clone(), random_weights(&cfg, seed))
}

/// GPTQT-quantize every linear so the LUT-GEMM kernels drive the core.
fn quantized_backend(model: &Model) -> BackendModel {
    let mut rng = gptqt::util::Rng::new(7);
    let mut layers = HashMap::new();
    for (name, _rows, cols) in model.cfg.all_linears() {
        let acts = Tensor::randn(2 * cols, cols, 1.0, &mut rng);
        let h = gptqt::quant::gptq::accumulate_hessian(&acts);
        let qcfg = QuantConfig { explore_grid: 2, ..QuantConfig::with_bits(3) };
        let q = quantize_layer(model.weights.expect(&name), &h, Method::Gptqt, &qcfg).unwrap();
        layers.insert(name, q);
    }
    BackendModel::quantized(model, layers)
}

fn sequential_prefill(bm: &BackendModel, tokens: &[u32], cache: &mut KvCache) -> Vec<f32> {
    let mut logits = Vec::new();
    for &t in tokens {
        logits = bm.decode_step(t, cache);
    }
    logits
}

#[test]
fn prefill_chunked_matches_sequential_all_chunk_sizes_and_families() {
    let prompt: Vec<u32> = (0..21u32).map(|i| 3 + (7 * i) % 60).collect();
    for fam in [Family::Opt, Family::Llama, Family::Bloom] {
        let m = tiny(fam, 42);
        let bm = BackendModel::dense(&m);
        let mut seq_cache = KvCache::new(&m.cfg);
        let seq_logits = sequential_prefill(&bm, &prompt, &mut seq_cache);
        for chunk in [1usize, 3, 16, prompt.len()] {
            let mut cache = KvCache::new(&m.cfg);
            let logits = bm.prefill_chunked(&prompt, &mut cache, chunk);
            assert_eq!(cache.len, seq_cache.len, "{fam:?} chunk {chunk}: cache length");
            assert_eq!(
                logits, seq_logits,
                "{fam:?} chunk {chunk}: chunked prefill logits diverged (bitwise)"
            );
        }
    }
}

#[test]
fn prefill_chunked_quantized_backend_is_bitwise_too() {
    let m = tiny(Family::Opt, 43);
    let bm = quantized_backend(&m);
    assert_eq!(bm.backend_label(), "gptqt-lut");
    let prompt: Vec<u32> = (0..17u32).map(|i| 5 + (11 * i) % 50).collect();
    let mut seq_cache = KvCache::new(&m.cfg);
    let seq_logits = sequential_prefill(&bm, &prompt, &mut seq_cache);
    for chunk in [1usize, 5, 17] {
        let mut cache = KvCache::new(&m.cfg);
        let logits = bm.prefill_chunked(&prompt, &mut cache, chunk);
        assert_eq!(
            logits, seq_logits,
            "LUT backend chunk {chunk}: chunked prefill diverged from sequential"
        );
    }
}

#[test]
fn kv_cache_state_is_identical_after_ragged_chunks() {
    // ragged chunk boundaries (1, 3, 16, remainder) must leave exactly
    // the K/V rows and length a sequential loop produces, and decoding
    // must continue bitwise-identically from that state
    let m = tiny(Family::Llama, 44); // RoPE makes positions load-bearing
    let bm = BackendModel::dense(&m);
    let prompt: Vec<u32> = (0..22u32).map(|i| 2 + (13 * i) % 60).collect();

    let mut seq_cache = KvCache::new(&m.cfg);
    sequential_prefill(&bm, &prompt, &mut seq_cache);

    let mut cache = KvCache::new(&m.cfg);
    let sizes = [1usize, 3, 16, 2];
    assert_eq!(sizes.iter().sum::<usize>(), prompt.len());
    let mut fed = 0usize;
    for &sz in &sizes {
        bm.forward_chunk(&prompt[fed..fed + sz], &mut cache);
        fed += sz;
        assert_eq!(cache.len, fed, "cache length after ragged chunk of {sz}");
    }
    assert_eq!(cache.len, seq_cache.len);
    for layer in 0..m.cfg.layers {
        for p in 0..cache.len {
            assert_eq!(
                cache.k_row(layer, p),
                seq_cache.k_row(layer, p),
                "K row {p} differs in layer {layer}"
            );
            assert_eq!(
                cache.v_row(layer, p),
                seq_cache.v_row(layer, p),
                "V row {p} differs in layer {layer}"
            );
        }
    }
    // continuation from the chunk-built cache matches the sequential one
    let a = bm.decode_step(9, &mut cache);
    let b = bm.decode_step(9, &mut seq_cache);
    assert_eq!(a, b, "decode after ragged chunked prefill diverged");
}

#[test]
fn forward_chunk_full_logits_match_model_forward() {
    for fam in [Family::Opt, Family::Llama, Family::Bloom] {
        let m = tiny(fam, 45);
        let bm = BackendModel::dense(&m);
        let tokens: Vec<u32> = (0..12u32).map(|i| 1 + (17 * i) % 60).collect();
        let full = m.forward(&tokens);
        // legacy pin: Model::forward delegates to the core now, so also
        // check against the surviving block-by-block implementation
        // (forward_hooked) — this is what catches a numerics bug that
        // shifts every core-derived path equally (e.g. a wrong RoPE or
        // ALiBi term for a non-Opt family)
        let legacy = m.forward_hooked(&tokens, None);
        assert_eq!(legacy.shape(), full.shape());
        let max_diff = legacy.max_abs_diff(&full);
        assert!(
            max_diff < 1e-4,
            "{fam:?}: chunk core drifted from the legacy block forward by {max_diff}"
        );
        // pieces of 5 against a warm cache must reproduce every row
        let mut cache = KvCache::new(&m.cfg);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for piece in tokens.chunks(5) {
            let logits = bm.forward_chunk(piece, &mut cache);
            for t in 0..logits.rows() {
                rows.push(logits.row(t).to_vec());
            }
        }
        assert_eq!(rows.len(), tokens.len());
        for (t, row) in rows.iter().enumerate() {
            assert_eq!(
                row.as_slice(),
                full.row(t),
                "{fam:?}: position {t} logits differ between chunked and full forward"
            );
        }
    }
}

#[test]
fn masked_forward_skips_logits_but_advances_caches_identically() {
    // the engine's mixed tick: one decoding sequence (needs logits), one
    // mid-prompt sequence (logits masked off) — the masked sequence's KV
    // cache must still advance exactly like an unmasked forward
    let m = tiny(Family::Opt, 48);
    let bm = BackendModel::dense(&m);
    let prompt_a: Vec<u32> = (0..9u32).map(|i| 3 + i).collect();
    let prompt_b: Vec<u32> = (0..6u32).map(|i| 7 + 2 * i).collect();

    let mut cache_a = KvCache::new(&m.cfg);
    let mut cache_b = KvCache::new(&m.cfg);
    bm.prefill(&prompt_a, &mut cache_a); // a is fully prefilled (decoding)
    let chunks: [&[u32]; 2] = [&[50u32], &prompt_b[..4]];
    let need = [true, false];
    let mut refs: Vec<&mut KvCache> = vec![&mut cache_a, &mut cache_b];
    let masked = bm.forward_chunks_masked(&chunks, &mut refs, &need);
    assert!(masked[0].is_some() && masked[1].is_none());
    assert_eq!(cache_b.len, 4);

    // reference: the same work without masking
    let mut ref_a = KvCache::new(&m.cfg);
    let mut ref_b = KvCache::new(&m.cfg);
    bm.prefill(&prompt_a, &mut ref_a);
    let a_logits = bm.decode_step(50, &mut ref_a);
    bm.forward_chunk(&prompt_b[..4], &mut ref_b);
    assert_eq!(masked[0].as_ref().unwrap(), &a_logits);
    for layer in 0..m.cfg.layers {
        for p in 0..4 {
            assert_eq!(
                cache_b.k_row(layer, p),
                ref_b.k_row(layer, p),
                "masked K row {p} diverged in layer {layer}"
            );
        }
    }
    // and the masked sequence continues bitwise-identically
    let cont = bm.forward_chunk(&prompt_b[4..], &mut cache_b);
    let cont_ref = bm.forward_chunk(&prompt_b[4..], &mut ref_b);
    assert_eq!(cont.data(), cont_ref.data());
}

#[test]
fn prefill_batch_matches_per_sequence_prefill() {
    let m = tiny(Family::Opt, 46);
    let bm = BackendModel::dense(&m);
    // different prompt lengths: short ones drop out of later rounds
    let prompts: [Vec<u32>; 3] = [
        (0..5u32).map(|i| 3 + i).collect(),
        (0..19u32).map(|i| 4 + (3 * i) % 55).collect(),
        (0..11u32).map(|i| 6 + (5 * i) % 50).collect(),
    ];
    let prefs: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let mut caches: Vec<KvCache> = (0..3).map(|_| KvCache::new(&m.cfg)).collect();
    let batch_logits = bm.prefill_batch(&prefs, &mut caches, 4);
    for (bi, prompt) in prompts.iter().enumerate() {
        let mut cache = KvCache::new(&m.cfg);
        let seq_logits = sequential_prefill(&bm, prompt, &mut cache);
        assert_eq!(caches[bi].len, prompt.len(), "seq {bi} cache length");
        assert_eq!(
            batch_logits[bi], seq_logits,
            "seq {bi}: batched prefill diverged from per-sequence"
        );
    }
}

#[test]
fn eval_ppl_backend_matches_dense_and_is_finite_quantized() {
    let m = tiny(Family::Opt, 47);
    let ecfg = EvalConfig { eval_windows: 2, eval_len: 24, ..EvalConfig::fast() };
    let windows: Vec<_> = eval_for(&ecfg, gptqt::data::Dataset::WikiSyn)
        .into_iter()
        .map(|mut w| {
            for t in w.tokens.iter_mut() {
                *t %= 64; // clamp to the tiny model's vocab
            }
            w
        })
        .collect();
    let dense_model_path = eval_ppl(&m, &windows);
    let dense_backend_path = eval_ppl_backend(&BackendModel::dense(&m), &windows);
    assert!(dense_model_path.is_finite());
    assert!(
        (dense_model_path - dense_backend_path).abs() < 1e-9,
        "dense ppl paths disagree: {dense_model_path} vs {dense_backend_path}"
    );
    // the deployment path: perplexity through the LUT-GEMM kernels
    let quant_ppl = eval_ppl_backend(&quantized_backend(&m), &windows);
    assert!(quant_ppl.is_finite(), "quantized backend ppl not finite");
    // 3-bit GPTQT on a tiny random model: close to dense, not wildly off
    assert!(
        quant_ppl < dense_model_path * 4.0 + 50.0,
        "quantized ppl {quant_ppl} implausibly far from dense {dense_model_path}"
    );
}
