//! Cross-format kernel parity: `gemv_dequant`, `gemv_lut`, and every
//! batched `gemm` path must match the dense f32 reference within fp
//! tolerance across shapes (including columns not divisible by the
//! pack/block sizes), bit-widths 2/3/4, and batch sizes 1/3/17 — plus
//! the exact invariant `gemm(B=1) == gemv` that the batched engine's
//! token-identical guarantee rests on.

use gptqt::kernels::gemv_dequant::{gemm_dequant, gemv_dequant};
use gptqt::kernels::gemv_lut::{gemm_lut, gemv_lut};
use gptqt::kernels::{gemm_f32, gemv_f32, DenseGemv, Gemv};
use gptqt::quant::linear::{rtn_quantize, IntLayer};
use gptqt::quant::pack::PackedBcLayer;
use gptqt::tensor::Tensor;
use gptqt::util::Rng;

/// Shapes exercising the unroll (cols % 4) and LUT-group (cols % 8)
/// tails as well as a partial GBLOCK (cols 130 → 17 groups).
const SHAPES: [(usize, usize); 4] = [(8, 16), (33, 77), (64, 130), (128, 256)];
const BITS: [u32; 3] = [2, 3, 4];
const BATCHES: [usize; 3] = [1, 3, 17];

fn random_batch(cols: usize, batch: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..batch)
        .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
        .collect()
}

fn as_refs(xs: &[Vec<f32>]) -> Vec<&[f32]> {
    xs.iter().map(|v| v.as_slice()).collect()
}

fn random_packed(rows: usize, cols: usize, planes: usize, seed: u64) -> PackedBcLayer {
    PackedBcLayer::random(rows, cols, planes, seed)
}

/// Tolerance scaled like the in-module kernel tests: fp roundoff grows
/// with the reduction length and the magnitude of the reference value.
fn tol(cols: usize, reference: f32) -> f32 {
    2e-4 * (cols as f32).sqrt() * (1.0 + reference.abs())
}

#[test]
fn dequant_gemv_and_gemm_match_dense_all_bits_shapes_batches() {
    let mut rng = Rng::new(9001);
    for &(rows, cols) in &SHAPES {
        for &bits in &BITS {
            let w = Tensor::randn(rows, cols, 1.0, &mut rng);
            let (q, grids) = rtn_quantize(&w, bits);
            let il = IntLayer::encode(&q, &grids, bits);
            let dense = DenseGemv::new(q.clone());
            for &batch in &BATCHES {
                let xs = random_batch(cols, batch, &mut rng);
                let refs = as_refs(&xs);
                let mut ys_int: Vec<Vec<f32>> =
                    (0..batch).map(|_| vec![0.0; rows]).collect();
                let mut ys_dense = ys_int.clone();
                gemm_dequant(&il, &refs, &mut ys_int);
                dense.gemm(&refs, &mut ys_dense);
                for bi in 0..batch {
                    // batched dequant vs dense reference: fp tolerance
                    for (r, (a, b)) in ys_int[bi].iter().zip(&ys_dense[bi]).enumerate() {
                        assert!(
                            (a - b).abs() < tol(cols, *b),
                            "{rows}x{cols} {bits}b B={batch} item {bi} row {r}: {a} vs {b}"
                        );
                    }
                    // batched vs per-item gemv: exact
                    let mut y_seq = vec![0.0; rows];
                    gemv_dequant(&il, &xs[bi], &mut y_seq);
                    assert_eq!(
                        ys_int[bi], y_seq,
                        "{rows}x{cols} {bits}b B={batch} item {bi}: gemm != gemv"
                    );
                }
            }
        }
    }
}

#[test]
fn lut_gemv_and_gemm_match_dense_all_planes_shapes_batches() {
    let mut rng = Rng::new(9002);
    for &(rows, cols) in &SHAPES {
        for &bits in &BITS {
            let planes = bits as usize;
            let layer = random_packed(rows, cols, planes, 31 * rows as u64 + cols as u64);
            let dense = layer.dequant();
            for &batch in &BATCHES {
                let xs = random_batch(cols, batch, &mut rng);
                let refs = as_refs(&xs);
                let mut ys_lut: Vec<Vec<f32>> =
                    (0..batch).map(|_| vec![0.0; rows]).collect();
                let mut ys_dense = ys_lut.clone();
                gemm_lut(&layer, &refs, &mut ys_lut);
                gemm_f32(&dense, &refs, &mut ys_dense);
                for bi in 0..batch {
                    for (r, (a, b)) in ys_lut[bi].iter().zip(&ys_dense[bi]).enumerate() {
                        assert!(
                            (a - b).abs() < tol(cols, *b),
                            "{rows}x{cols}x{planes} B={batch} item {bi} row {r}: {a} vs {b}"
                        );
                    }
                    let mut y_seq = vec![0.0; rows];
                    gemv_lut(&layer, &xs[bi], &mut y_seq);
                    assert_eq!(
                        ys_lut[bi], y_seq,
                        "{rows}x{cols}x{planes} B={batch} item {bi}: gemm != gemv"
                    );
                }
            }
        }
    }
}

#[test]
fn gemm_of_batch_one_equals_gemv_exactly_all_formats() {
    let mut rng = Rng::new(9003);
    let (rows, cols) = (33, 77);
    let w = Tensor::randn(rows, cols, 1.0, &mut rng);
    let (q, grids) = rtn_quantize(&w, 3);
    let il = IntLayer::encode(&q, &grids, 3);
    let packed = random_packed(rows, cols, 3, 55);
    let dense = DenseGemv::new(w.clone());
    let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();

    let backends: [&dyn Gemv; 3] = [&dense, &il, &packed];
    for backend in backends {
        let mut y_gemv = vec![0.0; rows];
        backend.gemv(&x, &mut y_gemv);
        let mut ys = vec![vec![0.0; rows]];
        backend.gemm(&[x.as_slice()], &mut ys);
        assert_eq!(
            ys[0],
            y_gemv,
            "gemm(B=1) must be bitwise identical to gemv for {}",
            backend.label()
        );
    }
}

#[test]
fn trait_default_gemm_fallback_matches_specialized_paths() {
    // A backend without an override must still satisfy the contract via
    // the per-item default loop; compare it against the dense override.
    struct LoopDense(Tensor);
    impl Gemv for LoopDense {
        fn rows(&self) -> usize {
            self.0.rows()
        }
        fn cols(&self) -> usize {
            self.0.cols()
        }
        fn gemv(&self, x: &[f32], y: &mut [f32]) {
            gemv_f32(&self.0, x, y);
        }
        fn streamed_bytes(&self) -> usize {
            self.0.len() * 4
        }
        fn label(&self) -> &'static str {
            "loop-dense"
        }
    }

    let mut rng = Rng::new(9004);
    let w = Tensor::randn(17, 29, 1.0, &mut rng);
    let fallback = LoopDense(w.clone());
    let specialized = DenseGemv::new(w);
    let xs = random_batch(29, 5, &mut rng);
    let refs = as_refs(&xs);
    let mut ys_a: Vec<Vec<f32>> = (0..5).map(|_| vec![0.0; 17]).collect();
    let mut ys_b = ys_a.clone();
    fallback.gemm(&refs, &mut ys_a);
    specialized.gemm(&refs, &mut ys_b);
    assert_eq!(ys_a, ys_b);
}
