//! Coordinator integration: N concurrent requests served through the
//! batched `Engine::step` must complete with outputs identical to the
//! sequential per-sequence loop (greedy sampling), and the engine must
//! actually batch (metrics record occupancy > 1).

use gptqt::coordinator::{CpuBackend, Engine, EngineConfig, Request, SamplingParams};
use gptqt::model::init::random_weights;
use gptqt::model::{presets, BackendModel, Model};
use gptqt::quant::{Method, QuantConfig};
use std::collections::HashMap;

fn test_model(seed: u64) -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.vocab = 64;
    cfg.max_seq = 48;
    Model::new(cfg.clone(), random_weights(&cfg, seed))
}

fn dense_engine(model: &Model, max_batch: usize) -> Engine<CpuBackend> {
    Engine::new(
        CpuBackend(BackendModel::dense(model)),
        EngineConfig { max_batch, total_blocks: 128, block_size: 8, ..Default::default() },
    )
}

fn requests(n: u64, prompt_len: usize, gen: usize) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let prompt: Vec<u32> = (0..prompt_len as u32)
                .map(|i| 3 + (5 * id as u32 + 7 * i) % 60)
                .collect();
            Request::new(id, prompt, gen)
        })
        .collect()
}

fn serve(engine: &mut Engine<CpuBackend>, reqs: Vec<Request>) -> HashMap<u64, Vec<u32>> {
    for req in reqs {
        engine.submit(req).unwrap();
    }
    let out = engine.run_to_completion().unwrap();
    engine.check_invariants().unwrap();
    out.into_iter().map(|r| (r.id, r.tokens)).collect()
}

#[test]
fn batched_engine_matches_sequential_loop_greedy() {
    let model = test_model(42);
    // max_batch = 1 degenerates the engine to the sequential
    // per-sequence loop; max_batch = 4 exercises the batched decode path
    let sequential = serve(&mut dense_engine(&model, 1), requests(6, 5, 7));
    let batched = serve(&mut dense_engine(&model, 4), requests(6, 5, 7));
    assert_eq!(sequential.len(), 6);
    assert_eq!(batched.len(), 6);
    for id in 0..6u64 {
        assert_eq!(
            batched[&id], sequential[&id],
            "request {id}: batched tokens diverged from sequential"
        );
    }
}

#[test]
fn batched_engine_records_occupancy_above_one() {
    let model = test_model(43);
    let mut engine = dense_engine(&model, 4);
    let out = serve(&mut engine, requests(8, 4, 6));
    assert_eq!(out.len(), 8);
    assert!(
        engine.metrics.max_batch_occupancy > 1,
        "engine never batched: max occupancy {}",
        engine.metrics.max_batch_occupancy
    );
    assert!(engine.metrics.mean_batch_occupancy() > 1.0);
    assert!(engine.metrics.decode_batches > 0);
    assert_eq!(engine.metrics.completed, 8);
}

#[test]
fn batched_engine_matches_sequential_through_lut_backend() {
    // the real serving configuration: packed binary-coded weights through
    // the batched LUT-GEMM path
    let model = test_model(44);
    let rng = gptqt::util::Rng::new(7);
    let build = || {
        let mut layers = HashMap::new();
        for (name, _rows, cols) in model.cfg.all_linears() {
            let acts = gptqt::tensor::Tensor::randn(2 * cols, cols, 1.0, &mut rng.clone());
            let h = gptqt::quant::gptq::accumulate_hessian(&acts);
            let qcfg = QuantConfig { explore_grid: 2, ..QuantConfig::with_bits(3) };
            let q = gptqt::quant::quantize_layer(
                model.weights.expect(&name),
                &h,
                Method::Gptqt,
                &qcfg,
            )
            .unwrap();
            layers.insert(name, q);
        }
        BackendModel::quantized(&model, layers)
    };
    let mk_engine = |bm: BackendModel, max_batch: usize| {
        Engine::new(
            CpuBackend(bm),
            EngineConfig { max_batch, total_blocks: 128, block_size: 8, ..Default::default() },
        )
    };
    let bm_a = build();
    assert_eq!(bm_a.backend_label(), "gptqt-lut");
    let sequential = serve(&mut mk_engine(bm_a, 1), requests(4, 4, 6));
    let batched = serve(&mut mk_engine(build(), 3), requests(4, 4, 6));
    for id in 0..4u64 {
        assert_eq!(
            batched[&id], sequential[&id],
            "request {id}: batched LUT serving diverged from sequential"
        );
    }
}

#[test]
fn batched_engine_handles_staggered_arrivals_and_sampling() {
    // requests arriving mid-flight join the running batch; seeded top-k
    // sampling stays per-sequence deterministic under batching
    let model = test_model(45);
    let run = |max_batch: usize| {
        let mut engine = dense_engine(&model, max_batch);
        for req in requests(3, 5, 6) {
            engine
                .submit(req.with_sampling(SamplingParams::TopK {
                    k: 8,
                    temperature: 1.0,
                    seed: 11,
                }))
                .unwrap();
        }
        // drive a few ticks before the late arrivals show up
        for _ in 0..3 {
            engine.step().unwrap();
        }
        // ids 0..10, keep 8/9 only
        let late: Vec<Request> = requests(10, 3, 4).into_iter().filter(|r| r.id >= 8).collect();
        for req in late {
            engine.submit(req).unwrap();
        }
        let mut out: Vec<(u64, Vec<u32>)> = engine
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        engine.check_invariants().unwrap();
        out.sort_by_key(|(id, _)| *id);
        out
    };
    let a = run(4);
    let b = run(1);
    assert_eq!(a.len(), 5);
    assert_eq!(a, b, "staggered batched serving diverged from sequential");
}
