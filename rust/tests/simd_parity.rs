//! Scalar-vs-SIMD parity for the vectorized kernels.
//!
//! **The pinned decision, per kernel:** all three formats (`f32`,
//! `dequant`, `lut`) keep the **bitwise** variant of the parity
//! contract. The AVX2 tier uses the same lane → accumulator mapping,
//! multiplies-then-adds (no FMA), and reduces lanes with the same
//! pinned tree as the scalar tier, so `assert_eq!` — not a ULP
//! tolerance — is the right check, at every batch size and on ragged
//! shapes (rows/cols not multiples of the vector width or GROUP). On a
//! host without AVX2 the dispatched path *is* the scalar path and these
//! tests pass trivially; on an AVX2 host they pin the real thing.
//!
//! `gemv == gemm(B=1)` stays bitwise as well (`kernel_parity.rs`), so
//! runtime dispatch can never change a served token.

use gptqt::kernels::gemv_dequant::{
    gemm_dequant, gemm_dequant_scalar, gemv_dequant, gemv_dequant_scalar,
};
use gptqt::kernels::gemv_lut::{gemm_lut, gemm_lut_scalar, gemv_lut, gemv_lut_scalar};
use gptqt::kernels::{gemm_f32, gemm_f32_scalar, gemv_f32, gemv_f32_scalar, simd};
use gptqt::quant::linear::{rtn_quantize, IntLayer};
use gptqt::quant::pack::PackedBcLayer;
use gptqt::tensor::Tensor;
use gptqt::util::Rng;

/// Ragged shapes: rows and cols off every alignment the kernels care
/// about (SIMD width 8, GROUP 8, GBLOCK 8 → 1031 = 128·8 + 7 columns,
/// 33 rows; plus tiny and sub-width cases).
const RAGGED: [(usize, usize); 4] = [(33, 1031), (7, 129), (12, 24), (1, 9)];
const BATCHES: [usize; 3] = [1, 3, 8];

fn random_batch(cols: usize, batch: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..batch)
        .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
        .collect()
}

fn as_refs(xs: &[Vec<f32>]) -> Vec<&[f32]> {
    xs.iter().map(|v| v.as_slice()).collect()
}

#[test]
fn f32_scalar_and_simd_tiers_are_bitwise_identical() {
    let mut rng = Rng::new(7001);
    for &(rows, cols) in &RAGGED {
        let w = Tensor::randn(rows, cols, 1.0, &mut rng);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
        let mut y_s = vec![0.0; rows];
        let mut y_d = vec![0.0; rows];
        gemv_f32_scalar(&w, &x, &mut y_s);
        gemv_f32(&w, &x, &mut y_d);
        assert_eq!(y_s, y_d, "{rows}x{cols} gemv tier {}", simd::tier().label());
        for &batch in &BATCHES {
            let xs = random_batch(cols, batch, &mut rng);
            let refs = as_refs(&xs);
            let mut ys_s: Vec<Vec<f32>> = (0..batch).map(|_| vec![0.0; rows]).collect();
            let mut ys_d = ys_s.clone();
            gemm_f32_scalar(&w, &refs, &mut ys_s);
            gemm_f32(&w, &refs, &mut ys_d);
            assert_eq!(ys_s, ys_d, "{rows}x{cols} B={batch} gemm");
        }
    }
}

#[test]
fn dequant_scalar_and_simd_tiers_are_bitwise_identical() {
    let mut rng = Rng::new(7002);
    for &(rows, cols) in &RAGGED {
        for bits in [2u32, 3] {
            let w = Tensor::randn(rows, cols, 1.0, &mut rng);
            let (q, grids) = rtn_quantize(&w, bits);
            let il = IntLayer::encode(&q, &grids, bits);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
            let mut y_s = vec![0.0; rows];
            let mut y_d = vec![0.0; rows];
            gemv_dequant_scalar(&il, &x, &mut y_s);
            gemv_dequant(&il, &x, &mut y_d);
            assert_eq!(y_s, y_d, "{rows}x{cols} {bits}b gemv");
            for &batch in &BATCHES {
                let xs = random_batch(cols, batch, &mut rng);
                let refs = as_refs(&xs);
                let mut ys_s: Vec<Vec<f32>> = (0..batch).map(|_| vec![0.0; rows]).collect();
                let mut ys_d = ys_s.clone();
                gemm_dequant_scalar(&il, &refs, &mut ys_s);
                gemm_dequant(&il, &refs, &mut ys_d);
                assert_eq!(ys_s, ys_d, "{rows}x{cols} {bits}b B={batch} gemm");
            }
        }
    }
}

#[test]
fn lut_scalar_and_simd_tiers_are_bitwise_identical() {
    let mut rng = Rng::new(7003);
    for &(rows, cols) in &RAGGED {
        for planes in [2usize, 3] {
            let layer =
                PackedBcLayer::random(rows, cols, planes, 900 + rows as u64 * 7 + cols as u64);
            assert!(layer.tail_is_neutral());
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
            let mut y_s = vec![0.0; rows];
            let mut y_d = vec![0.0; rows];
            gemv_lut_scalar(&layer, &x, &mut y_s);
            gemv_lut(&layer, &x, &mut y_d);
            assert_eq!(y_s, y_d, "{rows}x{cols}x{planes} gemv");
            for &batch in &BATCHES {
                let xs = random_batch(cols, batch, &mut rng);
                let refs = as_refs(&xs);
                let mut ys_s: Vec<Vec<f32>> = (0..batch).map(|_| vec![0.0; rows]).collect();
                let mut ys_d = ys_s.clone();
                gemm_lut_scalar(&layer, &refs, &mut ys_s);
                gemm_lut(&layer, &refs, &mut ys_d);
                assert_eq!(ys_s, ys_d, "{rows}x{cols}x{planes} B={batch} gemm");
            }
        }
    }
}

#[test]
fn lut_simd_path_stays_correct_vs_dense_on_ragged_shapes() {
    // Parity alone could hide a shared bug; anchor the dispatched path
    // against the dense dequantized reference on the big ragged shape.
    let mut rng = Rng::new(7004);
    let (rows, cols, planes) = (33usize, 1031usize, 3usize);
    let layer = PackedBcLayer::random(rows, cols, planes, 77007);
    let dense = layer.dequant();
    let xs = random_batch(cols, 3, &mut rng);
    let refs = as_refs(&xs);
    let mut ys: Vec<Vec<f32>> = (0..3).map(|_| vec![0.0; rows]).collect();
    let mut ys_ref = ys.clone();
    gemm_lut(&layer, &refs, &mut ys);
    gemm_f32(&dense, &refs, &mut ys_ref);
    for bi in 0..3 {
        for (r, (a, b)) in ys[bi].iter().zip(&ys_ref[bi]).enumerate() {
            let tol = 2e-4 * (cols as f32).sqrt() * (1.0 + b.abs());
            assert!((a - b).abs() < tol, "item {bi} row {r}: {a} vs {b}");
        }
    }
}

#[test]
fn threaded_aligned_partition_keeps_bitwise_parity() {
    // 2051×1031 at batch 8 clears PAR_MIN_WORK, so the dispatched gemm
    // runs row-partitioned on the pool with SIMD-block-aligned chunks
    // (ragged final chunk); results must still match the single-threaded
    // scalar tier bit-for-bit, and gemm(B=1) == gemv must survive.
    let mut rng = Rng::new(7005);
    let (rows, cols, planes) = (2051usize, 1031usize, 3usize);
    assert!(rows * cols * 8 >= gptqt::kernels::PAR_MIN_WORK);
    let layer = PackedBcLayer::random(rows, cols, planes, 424242);
    let xs = random_batch(cols, 8, &mut rng);
    let refs = as_refs(&xs);
    let mut ys_s: Vec<Vec<f32>> = (0..8).map(|_| vec![0.0; rows]).collect();
    let mut ys_d = ys_s.clone();
    gemm_lut_scalar(&layer, &refs, &mut ys_s);
    gemm_lut(&layer, &refs, &mut ys_d);
    assert_eq!(ys_s, ys_d, "threaded ragged gemm_lut scalar vs dispatched");
    for bi in 0..8 {
        let mut y = vec![0.0; rows];
        gemv_lut(&layer, &xs[bi], &mut y);
        assert_eq!(ys_d[bi], y, "item {bi}: gemm != gemv under threading");
    }
}

#[test]
fn detected_tier_is_exercised_not_assumed() {
    // Purely informational guard: the suite is only meaningful if the
    // dispatcher actually resolves; print the tier for CI logs.
    let t = simd::tier();
    println!("simd tier under test: {}", t.label());
    assert!(matches!(t, simd::SimdTier::Scalar | simd::SimdTier::Avx2));
}

#[test]
fn elementwise_dot_add_assign_axpy_tiers_are_bitwise_identical() {
    // The row primitives behind every kernel above: `dot`, `add_assign`
    // and `axpy` carry the same bitwise scalar↔AVX2 contract directly,
    // so the lint scalar-twin rule counts this as their coverage.
    let mut rng = Rng::new(7005);
    for &(_, n) in &RAGGED {
        let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        assert_eq!(
            simd::dot(&a, &b).to_bits(),
            simd::dot_scalar(&a, &b).to_bits(),
            "dot n={n} tier {}",
            simd::tier().label()
        );
        let mut x_s = a.clone();
        let mut x_d = a.clone();
        simd::add_assign_scalar(&mut x_s, &b);
        simd::add_assign(&mut x_d, &b);
        assert_eq!(x_s, x_d, "add_assign n={n}");
        let mut y_s = a.clone();
        let mut y_d = a.clone();
        simd::axpy_scalar(&mut y_s, 0.75, &b);
        simd::axpy(&mut y_d, 0.75, &b);
        assert_eq!(y_s, y_d, "axpy n={n}");
    }
}
