//! Cross-layer numerics: the AOT-compiled XLA executables (L1 Pallas +
//! L2 JAX, lowered at build time) must agree with the rust reference
//! forward (L3) on the same weights — the contract that makes the fused
//! binary coding servable through either path.
//!
//! Skips gracefully when `make artifacts` has not run.

use gptqt::model::{load_or_init, KvCache};
use gptqt::runtime::{artifacts_present, Runtime};

fn artifacts_dir() -> std::path::PathBuf {
    // cargo test runs from the package root
    std::path::PathBuf::from("artifacts")
}

/// PJRT client, or `None` to skip: without the `pjrt` feature the stub
/// runtime always errors, and even with artifacts on disk there is
/// nothing to execute them with.
fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: {e}");
            None
        }
    }
}

#[test]
fn logits_artifact_matches_rust_forward() {
    let dir = artifacts_dir();
    if !artifacts_present(&dir, "opt-nano") {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    }
    let (model, _) = load_or_init("opt-nano", &dir, 0).unwrap();
    let Some(rt) = runtime_or_skip() else { return };
    let compiled = rt.load_model(&dir, &model).unwrap();
    let seq = compiled.meta.seq;

    // deterministic pseudo-random token window
    let tokens: Vec<u32> = (0..seq as u32)
        .map(|i| 3 + (i * 2654435761u32 % 997) % (model.cfg.vocab as u32 - 3))
        .collect();

    let hlo = compiled.logits(&tokens).unwrap();
    let rust = model.forward(&tokens);
    assert_eq!(hlo.shape(), rust.shape());
    let max_diff = hlo.max_abs_diff(&rust);
    // same math in f32 through two compilers: expect ~1e-3 worst case
    assert!(
        max_diff < 5e-2,
        "XLA vs rust forward diverged: max |Δlogit| = {max_diff}"
    );
    // perplexity-level agreement (the metric experiments actually use)
    let (nll_h, n) = gptqt::model::forward::nll_from_logits(&hlo, &tokens);
    let (nll_r, _) = gptqt::model::forward::nll_from_logits(&rust, &tokens);
    let (p_h, p_r) = ((nll_h / n as f64).exp(), (nll_r / n as f64).exp());
    assert!(
        (p_h - p_r).abs() / p_r < 1e-3,
        "ppl mismatch: hlo {p_h} vs rust {p_r}"
    );
}

#[test]
fn decode_artifact_matches_rust_decode() {
    let dir = artifacts_dir();
    if !artifacts_present(&dir, "opt-nano") {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    }
    let (model, _) = load_or_init("opt-nano", &dir, 0).unwrap();
    let Some(rt) = runtime_or_skip() else { return };
    let compiled = rt.load_model(&dir, &model).unwrap();

    let bm = gptqt::model::BackendModel::dense(&model);
    let mut rust_cache = KvCache::new(&model.cfg);
    let mut dev_kv = compiled.new_kv().unwrap();

    let tokens = [5u32, 17, 42, 100, 7, 9, 300, 11];
    for &t in &tokens {
        let hlo_logits = compiled.decode(&mut dev_kv, t).unwrap();
        let rust_logits = bm.decode_step(t, &mut rust_cache);
        assert_eq!(hlo_logits.len(), rust_logits.len());
        let max_diff = hlo_logits
            .iter()
            .zip(&rust_logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-2, "decode diverged at token {t}: {max_diff}");
        // greedy choices must agree (what generation actually consumes)
        let am_h = gptqt::coordinator::sampler::argmax(&hlo_logits);
        let am_r = gptqt::coordinator::sampler::argmax(&rust_logits);
        assert_eq!(am_h, am_r, "greedy token diverged after feeding {t}");
    }
}

#[test]
fn pjrt_engine_serves_requests() {
    let dir = artifacts_dir();
    if !artifacts_present(&dir, "opt-nano") {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    }
    use gptqt::coordinator::{Engine, EngineConfig, PjrtBackend, Request};
    let (model, _) = load_or_init("opt-nano", &dir, 0).unwrap();
    let Some(rt) = runtime_or_skip() else { return };
    let compiled = rt.load_model(&dir, &model).unwrap();
    let mut engine = Engine::new(
        PjrtBackend(compiled),
        EngineConfig { max_batch: 2, ..Default::default() },
    );
    for id in 0..3u64 {
        engine
            .submit(Request::new(id, vec![4 + id as u32, 9, 13, 22], 6))
            .unwrap();
    }
    let out = engine.run_to_completion().unwrap();
    assert_eq!(out.len(), 3);
    assert!(engine.check_invariants().is_ok());
    assert!(out.iter().all(|r| !r.tokens.is_empty()));
}
