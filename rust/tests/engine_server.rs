//! Streaming session API integration: the `Server` front-end over the
//! `Backend`-trait engine must stream per-token events whose
//! concatenation is bitwise identical to the offline
//! `run_to_completion` responses (dense and gptqt-lut backends),
//! cancellation must return every paged-KV block to the pool,
//! deadlines must finish with the right reason, and the adaptive
//! schedule policy must respect its chunk bound without changing a
//! single token.

use gptqt::coordinator::{
    CpuBackend, Engine, EngineConfig, Event, FinishReason, Request, SamplingParams,
    SchedulePolicyKind, Server,
};
use gptqt::eval::speed::{build_variant, SpeedVariant};
use gptqt::model::init::random_weights;
use gptqt::model::{presets, BackendModel, Model};
use std::collections::HashMap;
use std::time::Duration;

fn test_model(seed: u64) -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.vocab = 64;
    cfg.max_seq = 48;
    Model::new(cfg.clone(), random_weights(&cfg, seed))
}

fn cfg(max_batch: usize) -> EngineConfig {
    EngineConfig { max_batch, total_blocks: 128, block_size: 8, ..Default::default() }
}

/// Mixed greedy / seeded top-k requests (the "same seeds" of the
/// bitwise-parity requirement).
fn requests(n: u64, prompt_len: usize, gen: usize) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let prompt: Vec<u32> = (0..prompt_len as u32)
                .map(|i| 3 + (5 * id as u32 + 7 * i) % 60)
                .collect();
            let req = Request::new(id, prompt, gen);
            if id % 2 == 0 {
                req
            } else {
                req.with_sampling(SamplingParams::TopK { k: 8, temperature: 1.0, seed: 100 + id })
            }
        })
        .collect()
}

/// Offline reference: drive the engine directly, collect terminal
/// responses.
fn engine_reference(
    bm: BackendModel,
    max_batch: usize,
    reqs: Vec<Request>,
) -> HashMap<u64, Vec<u32>> {
    let mut engine = Engine::new(CpuBackend(bm), cfg(max_batch));
    for r in reqs {
        engine.submit(r).unwrap();
    }
    let out = engine.run_to_completion().unwrap();
    engine.check_invariants().unwrap();
    out.into_iter().map(|r| (r.id, r.tokens)).collect()
}

/// Streaming path: spawn a server, concatenate each request's Token
/// events, and cross-check them against its own terminal response.
fn server_streamed(
    bm: BackendModel,
    max_batch: usize,
    reqs: Vec<Request>,
) -> HashMap<u64, Vec<u32>> {
    let server = Server::spawn(CpuBackend(bm), cfg(max_batch));
    let handles: Vec<_> = reqs.into_iter().map(|r| server.submit(r)).collect();
    let mut out = HashMap::new();
    for h in handles {
        let id = h.id();
        let mut streamed: Vec<u32> = Vec::new();
        let mut terminal = None;
        for ev in h.events() {
            match ev {
                Event::Started { id: eid, queue_secs } => {
                    assert_eq!(eid, id);
                    assert!(queue_secs >= 0.0);
                }
                Event::Token { id: eid, token, .. } => {
                    assert_eq!(eid, id, "token routed to the wrong handle");
                    streamed.push(token);
                }
                Event::Finished(r) => terminal = Some(r),
                Event::Rejected { error, .. } => panic!("request {id} rejected: {error:?}"),
            }
        }
        let r = terminal.expect("stream must end with a terminal event");
        assert_eq!(
            r.tokens, streamed,
            "request {id}: terminal response disagrees with its own token stream"
        );
        out.insert(id, streamed);
    }
    let m = server.shutdown();
    assert_eq!(m.cancelled_total, 0);
    out
}

#[test]
fn streamed_tokens_bitwise_match_offline_dense() {
    let model = test_model(42);
    let reference = engine_reference(BackendModel::dense(&model), 4, requests(6, 5, 7));
    let streamed = server_streamed(BackendModel::dense(&model), 4, requests(6, 5, 7));
    assert_eq!(streamed.len(), 6);
    for id in 0..6u64 {
        assert_eq!(
            streamed[&id], reference[&id],
            "request {id}: streamed tokens diverged from run_to_completion"
        );
    }
}

#[test]
fn streamed_tokens_bitwise_match_offline_lut() {
    // the real serving configuration: packed binary-coded weights
    // through the batched LUT-GEMM path
    let model = test_model(44);
    let variant = SpeedVariant::GptqtLut { bits: 3 };
    let bm = build_variant(&model, variant, 7);
    assert_eq!(bm.backend_label(), "gptqt-lut");
    let reference = engine_reference(bm, 3, requests(4, 4, 6));
    let streamed = server_streamed(build_variant(&model, variant, 7), 3, requests(4, 4, 6));
    for id in 0..4u64 {
        assert_eq!(
            streamed[&id], reference[&id],
            "request {id}: streamed LUT serving diverged from run_to_completion"
        );
    }
}

#[test]
fn cancel_mid_decode_returns_every_kv_block() {
    let model = test_model(45);
    let mut engine = Engine::new(
        CpuBackend(BackendModel::dense(&model)),
        EngineConfig { eos_token: u32::MAX, ..cfg(4) },
    );
    let total_free = engine.kv().free_blocks();
    for r in requests(4, 6, 30) {
        engine.submit(r).unwrap();
    }
    // well into decode for every sequence
    for _ in 0..5 {
        engine.step().unwrap();
    }
    assert!(engine.kv().used_blocks() > 0);
    // cancel every running sequence mid-decode
    for id in 0..4u64 {
        assert!(engine.cancel(id), "request {id} should be running");
        engine.check_invariants().unwrap();
    }
    assert_eq!(
        engine.kv().free_blocks(),
        total_free,
        "cancel must return every paged-KV block to the pool"
    );
    // terminal events drain with reason Cancelled and partial tokens
    let mut cancelled = 0;
    while engine.has_work() {
        for ev in engine.step().unwrap() {
            if let Event::Finished(r) = ev {
                assert_eq!(r.finish, FinishReason::Cancelled);
                assert!(!r.tokens.is_empty(), "mid-decode cancel keeps streamed tokens");
                cancelled += 1;
            }
        }
    }
    assert_eq!(cancelled, 4);
    assert_eq!(engine.metrics.cancelled_total, 4);
    engine.check_invariants().unwrap();
}

#[test]
fn server_cancel_queued_request_is_terminal() {
    // max_batch 1 pins request 1 in the queue while request 0 runs, so
    // the FIFO control channel makes the cancel deterministic
    let model = test_model(46);
    let server = Server::spawn(
        CpuBackend(BackendModel::dense(&model)),
        EngineConfig { eos_token: u32::MAX, ..cfg(1) },
    );
    let long = server.submit(Request::new(0, vec![4; 6], 40));
    let doomed = server.submit(Request::new(1, vec![4; 6], 4));
    doomed.cancel();
    let r = doomed.wait().expect("cancelled stream still terminates");
    assert_eq!(r.finish, FinishReason::Cancelled);
    assert!(r.tokens.is_empty());
    assert_eq!(long.wait().unwrap().finish, FinishReason::Length);
    let m = server.shutdown();
    assert_eq!(m.cancelled_total, 1);
    assert_eq!(m.completed, 1);
}

#[test]
fn deadline_expiry_finishes_with_deadline_reason() {
    let model = test_model(47);
    // server level: an already-expired deadline is deterministic
    let server = Server::spawn(CpuBackend(BackendModel::dense(&model)), cfg(2));
    let h = server.submit(Request::new(1, vec![4; 5], 8).with_deadline(Duration::ZERO));
    let r = h.wait().expect("expired stream still terminates");
    assert_eq!(r.finish, FinishReason::DeadlineExpired);
    assert!(r.tokens.is_empty());
    let m = server.shutdown();
    assert_eq!(m.expired_total, 1);

    // engine level: expiry mid-generation after real tokens streamed
    let mut engine = Engine::new(
        CpuBackend(BackendModel::dense(&model)),
        EngineConfig { eos_token: u32::MAX, ..cfg(2) },
    );
    engine
        .submit(Request::new(1, vec![4; 5], 40).with_deadline(Duration::from_millis(25)))
        .unwrap();
    engine.step().unwrap();
    std::thread::sleep(Duration::from_millis(35));
    let out = engine.run_to_completion().unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].finish, FinishReason::DeadlineExpired);
    assert!(out[0].tokens.len() < 40);
    engine.check_invariants().unwrap();
    assert_eq!(engine.metrics.expired_total, 1);
}

#[test]
fn adaptive_chunk_respects_bound_and_keeps_tokens() {
    let model = test_model(49);
    let serve = |policy: SchedulePolicyKind| {
        let mut engine = Engine::new(
            CpuBackend(BackendModel::dense(&model)),
            EngineConfig { prefill_chunk: 8, policy, ..cfg(4) },
        );
        for r in requests(6, 20, 6) {
            engine.submit(r).unwrap();
        }
        let out = engine.run_to_completion().unwrap();
        engine.check_invariants().unwrap();
        assert!(
            engine.metrics.max_tick_chunk >= 1 && engine.metrics.max_tick_chunk <= 8,
            "{policy:?}: tick chunk {} escaped the configured bound 8",
            engine.metrics.max_tick_chunk
        );
        out.into_iter().map(|r| (r.id, r.tokens)).collect::<HashMap<_, _>>()
    };
    let fixed = serve(SchedulePolicyKind::Fixed);
    let adaptive = serve(SchedulePolicyKind::Adaptive);
    assert_eq!(fixed, adaptive, "schedule policy must never change generated tokens");
}

#[test]
fn queue_wait_is_visible_in_started_events_and_metrics() {
    let model = test_model(50);
    let mut engine = Engine::new(
        CpuBackend(BackendModel::dense(&model)),
        EngineConfig { eos_token: u32::MAX, ..cfg(1) },
    );
    engine.submit(Request::new(0, vec![4; 4], 30)).unwrap();
    engine.step().unwrap(); // request 0 takes the only slot
    engine.submit(Request::new(1, vec![4; 4], 2).with_priority(2)).unwrap();
    engine.submit(Request::new(2, vec![4; 4], 2).with_priority(0)).unwrap();
    engine.submit(Request::new(3, vec![4; 4], 2).with_priority(1)).unwrap();
    let mut started = Vec::new();
    while engine.has_work() {
        for ev in engine.step().unwrap() {
            if let Event::Started { id, queue_secs } = ev {
                assert!(queue_secs >= 0.0);
                started.push(id);
            }
        }
    }
    assert_eq!(started, vec![2, 3, 1], "admission must follow priority, then FIFO");
    assert!(engine.metrics.queue_time.count() >= 4, "queue waits recorded");
}
