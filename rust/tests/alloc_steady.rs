//! Steady-state allocation regression tests. This binary installs
//! [`CountingAllocator`] as its global allocator, so every heap event in
//! the process is counted; the engine's persistent per-tick buffers and
//! the batched-decode workspace must hold allocation traffic flat from
//! one decode window to the next (a per-tick leak or per-tick buffer
//! rebuild shows up as window-over-window growth).

use gptqt::coordinator::{CpuBackend, Engine, EngineConfig, Request};
use gptqt::eval::speed::{build_variant, measure_decode_batch, SpeedVariant};
use gptqt::model::init::random_weights;
use gptqt::model::{presets, BackendModel, Model};
use gptqt::util::alloc::{self, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

// the counters are process-global, so concurrent tests would pollute
// each other's windows — take this for any measured region
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn test_model(seed: u64) -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.vocab = 64;
    cfg.max_seq = 48;
    Model::new(cfg.clone(), random_weights(&cfg, seed))
}

/// Pure decode ticks through `Engine::step` with a full running set:
/// after warmup, a window of ticks must allocate no more than the
/// previous equal window — the per-tick chunk/need/borrow vectors are
/// persistent state, not per-tick rebuilds.
#[test]
fn engine_step_decode_ticks_hold_allocations_flat() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let model = test_model(21);
    let mut engine = Engine::new(
        CpuBackend(BackendModel::dense(&model)),
        EngineConfig {
            max_batch: 4,
            block_size: 8,
            total_blocks: 64,
            eos_token: u32::MAX, // run the full 40 decode ticks
            ..Default::default()
        },
    );
    for id in 0..4u64 {
        let prompt: Vec<u32> = (0..6u32).map(|i| 3 + (5 * id as u32 + 7 * i) % 60).collect();
        engine.submit(Request::new(id, prompt, 40)).unwrap();
    }
    // admission + prefill + a few decode ticks to settle every lazily
    // grown structure (event vecs, sampler state, tick buffers)
    for _ in 0..6 {
        engine.step().unwrap();
    }
    assert!(alloc::enabled(), "counting allocator must be installed in this binary");
    let s0 = alloc::snapshot();
    for _ in 0..8 {
        engine.step().unwrap();
    }
    let s1 = alloc::snapshot();
    for _ in 0..8 {
        engine.step().unwrap();
    }
    let s2 = alloc::snapshot();
    let w1 = s1.allocs_since(&s0);
    let w2 = s2.allocs_since(&s1);
    assert!(w1 > 0, "decode ticks still produce logits/event allocations");
    assert!(
        w2 <= w1 + 4,
        "second decode window allocated more than the first: {w2} vs {w1} \
         (per-tick buffers are growing instead of being reused)"
    );
    // all four sequences must still be mid-generation, so both windows
    // really were pure decode ticks
    assert!(engine.has_work());
    engine.run_to_completion().unwrap();
    engine.check_invariants().unwrap();
}

/// `measure_decode_batch` reports its own allocation rate; under the
/// counting allocator the figure must be real, small, and identical
/// between two identical runs (the shared `ForwardScratch` workspace
/// keeps the timed loop at its steady-state floor).
#[test]
fn measure_decode_batch_reports_steady_alloc_rate() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let model = test_model(22);
    let bm = build_variant(&model, SpeedVariant::Full, 1);
    let r1 = measure_decode_batch(&model.cfg, &bm, SpeedVariant::Full, 4, 4, 10, 2);
    let r2 = measure_decode_batch(&model.cfg, &bm, SpeedVariant::Full, 4, 4, 10, 2);
    assert!(r1.allocs_per_step > 0.0, "logits vectors alone allocate each step");
    assert!(
        r2.allocs_per_step <= r1.allocs_per_step + 2.0,
        "repeat run allocated more per step: {} vs {}",
        r2.allocs_per_step,
        r1.allocs_per_step
    );
    assert!(
        r2.allocs_per_step < 64.0,
        "decode step allocation rate blew past the steady-state floor: {}",
        r2.allocs_per_step
    );
}
