//! Attention-subsystem parity: the head-major KV layout, the
//! scalar↔AVX2 attention kernels, the (row, head) pool fan-out, and the
//! reusable forward workspace must all be invisible in served tokens.
//!
//! Three layers of pins:
//! 1. kernel — `qk_dots`/`av_accumulate` scalar and dispatched tiers are
//!    `assert_eq!`-bitwise across ragged head dims and context lengths;
//! 2. threaded — a forward big enough to cross the attention
//!    parallelism threshold is bitwise-identical to the sequential
//!    per-token loop (which stays under it);
//! 3. end-to-end — mixed prefill/decode ticks, RoPE (Llama) and ALiBi
//!    (Bloom) families, dense and LUT backends, and workspace reuse
//!    across ragged tick shapes all reproduce the sequential reference
//!    exactly, with the head-major caches holding identical state.

use gptqt::kernels::attn::{av_accumulate, av_accumulate_scalar, qk_dots, qk_dots_scalar};
use gptqt::model::init::random_weights;
use gptqt::model::{presets, BackendModel, Family, ForwardScratch, KvCache, Model};
use gptqt::quant::{quantize_layer, Method, QuantConfig};
use gptqt::tensor::Tensor;
use gptqt::util::Rng;
use std::collections::HashMap;

fn tiny(family: Family, seed: u64) -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.family = family;
    cfg.vocab = 64;
    cfg.max_seq = 48;
    Model::new(cfg.clone(), random_weights(&cfg, seed))
}

/// GPTQT-quantize every linear so the LUT-GEMM kernels drive the core.
fn lut_backend(model: &Model) -> BackendModel {
    let mut rng = Rng::new(9);
    let mut layers = HashMap::new();
    for (name, _rows, cols) in model.cfg.all_linears() {
        let acts = Tensor::randn(2 * cols, cols, 1.0, &mut rng);
        let h = gptqt::quant::gptq::accumulate_hessian(&acts);
        let qcfg = QuantConfig { explore_grid: 2, ..QuantConfig::with_bits(3) };
        let q = quantize_layer(model.weights.expect(&name), &h, Method::Gptqt, &qcfg).unwrap();
        layers.insert(name, q);
    }
    BackendModel::quantized(model, layers)
}

#[test]
fn qk_dots_scalar_and_dispatched_are_bitwise_equal() {
    let mut rng = Rng::new(71);
    for dh in [3usize, 8, 12, 31, 32, 64, 96] {
        for ctx in [1usize, 2, 9, 63, 128, 517] {
            let q: Vec<f32> = (0..dh).map(|_| rng.normal_f32()).collect();
            let kstrip: Vec<f32> = (0..ctx * dh).map(|_| rng.normal_f32()).collect();
            let scale = 1.0 / (dh as f32).sqrt();
            for (slope, pos) in [(0.0f32, ctx - 1), (-0.25, ctx + 3)] {
                let mut s_scalar = vec![0.0f32; ctx];
                let mut s_disp = vec![0.0f32; ctx];
                qk_dots_scalar(&q, &kstrip, scale, slope, pos, &mut s_scalar);
                qk_dots(&q, &kstrip, scale, slope, pos, &mut s_disp);
                for (j, (a, b)) in s_scalar.iter().zip(&s_disp).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "qk_dots dh={dh} ctx={ctx} slope={slope} j={j}"
                    );
                }
            }
        }
    }
}

#[test]
fn av_accumulate_scalar_and_dispatched_are_bitwise_equal() {
    let mut rng = Rng::new(72);
    for dh in [3usize, 8, 12, 31, 32, 64, 96] {
        for ctx in [1usize, 2, 9, 63, 128, 517] {
            let w: Vec<f32> = (0..ctx).map(|_| rng.normal_f32()).collect();
            let vstrip: Vec<f32> = (0..ctx * dh).map(|_| rng.normal_f32()).collect();
            let base: Vec<f32> = (0..dh).map(|_| rng.normal_f32()).collect();
            let mut out_scalar = base.clone();
            let mut out_disp = base;
            av_accumulate_scalar(&w, &vstrip, &mut out_scalar);
            av_accumulate(&w, &vstrip, &mut out_disp);
            for (d, (a, b)) in out_scalar.iter().zip(&out_disp).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "av_accumulate dh={dh} ctx={ctx} d={d}"
                );
            }
        }
    }
}

#[test]
fn threaded_attention_is_bitwise_identical_to_sequential() {
    // One big prefill chunk whose attention work crosses the pool
    // fan-out threshold (Σ(p+1)·dh·heads·2 ≈ 20M ≥ 2²¹ at 280 tokens on
    // opt-mini), against a sequential per-token loop whose per-step
    // attention stays far below it — so on multicore machines the two
    // sides run the threaded and sequential paths respectively (and on
    // single-core machines both run sequentially: same contract).
    let mut cfg = presets::by_name("opt-mini").unwrap();
    cfg.family = Family::Llama; // RoPE makes positions load-bearing
    cfg.vocab = 64;
    cfg.max_seq = 300;
    let model = Model::new(cfg.clone(), random_weights(&cfg, 81));
    let bm = BackendModel::dense(&model);
    let tokens: Vec<u32> = (0..280u32).map(|i| 3 + (11 * i) % 60).collect();

    let mut seq_cache = KvCache::new(&cfg);
    let mut seq_last = Vec::new();
    for &t in &tokens {
        seq_last = bm.decode_step(t, &mut seq_cache);
    }

    let mut chunk_cache = KvCache::new(&cfg);
    let logits = bm.forward_chunk(&tokens, &mut chunk_cache);
    assert_eq!(chunk_cache.len, seq_cache.len);
    assert_eq!(
        logits.row(tokens.len() - 1),
        seq_last.as_slice(),
        "threaded chunk attention diverged from the sequential loop"
    );
    // and the head-major caches hold identical state
    for layer in 0..cfg.layers {
        for p in [0usize, 1, 137, 279] {
            assert_eq!(
                chunk_cache.k_row(layer, p),
                seq_cache.k_row(layer, p),
                "K layer {layer} pos {p}"
            );
            assert_eq!(
                chunk_cache.v_row(layer, p),
                seq_cache.v_row(layer, p),
                "V layer {layer} pos {p}"
            );
        }
    }
}

#[test]
fn mixed_ticks_match_sequential_all_families_dense_and_lut() {
    // The engine's tick shape: one decoding sequence (chunk len 1) and
    // one prefilling sequence (chunk len 3) advance through a single
    // masked forward per tick, reusing one workspace — tokens and KV
    // state must be bitwise those of per-sequence sequential serving.
    for fam in [Family::Opt, Family::Llama, Family::Bloom] {
        let model = tiny(fam, 83);
        for quantized in [false, true] {
            let bm = if quantized {
                lut_backend(&model)
            } else {
                BackendModel::dense(&model)
            };
            let prompt_a: Vec<u32> = (0..10u32).map(|i| 3 + (7 * i) % 60).collect();
            let prompt_b: Vec<u32> = (0..9u32).map(|i| 5 + (13 * i) % 55).collect();

            // sequential reference
            let mut ref_a = KvCache::new(&model.cfg);
            let mut ref_b = KvCache::new(&model.cfg);
            let mut ref_logits_a = Vec::new();
            for &t in &prompt_a {
                ref_logits_a = bm.decode_step(t, &mut ref_a);
            }
            let mut ref_logits_b = Vec::new();
            for &t in &prompt_b {
                ref_logits_b = bm.decode_step(t, &mut ref_b);
            }

            // mixed ticks: a decodes (greedy), b prefills 3 tokens/tick
            let mut scratch = ForwardScratch::new();
            let mut cache_a = KvCache::new(&model.cfg);
            let mut cache_b = KvCache::new(&model.cfg);
            bm.prefill(&prompt_a, &mut cache_a);
            // a's decode stream starts from the greedy continuation of
            // its prompt (same on both sides by construction)
            let mut a_tok = gptqt::coordinator::sampler::argmax(&ref_logits_a);
            let mut fed = 0usize;
            let mut last_b = Vec::new();
            let mut seq_a_cache = ref_a; // continue the reference side by side
            while fed < prompt_b.len() {
                let end = (fed + 3).min(prompt_b.len());
                let chunks: [&[u32]; 2] = [std::slice::from_ref(&a_tok), &prompt_b[fed..end]];
                let need = [true, end == prompt_b.len()];
                let mut caches: Vec<&mut KvCache> = vec![&mut cache_a, &mut cache_b];
                let out = bm.forward_chunks_masked_with(&chunks, &mut caches, &need, &mut scratch);
                // reference: the same decode step, alone
                let seq_a_logits = bm.decode_step(a_tok, &mut seq_a_cache);
                let got_a = out[0].as_ref().expect("decoding sequence has logits");
                assert_eq!(
                    got_a, &seq_a_logits,
                    "{fam:?} quantized={quantized}: mixed-tick decode logits diverged"
                );
                a_tok = gptqt::coordinator::sampler::argmax(got_a);
                if let Some(l) = &out[1] {
                    last_b = l.clone();
                }
                fed = end;
            }
            assert_eq!(
                last_b, ref_logits_b,
                "{fam:?} quantized={quantized}: prefilled-in-ticks logits diverged"
            );
            assert_eq!(cache_b.len, prompt_b.len());
            for layer in 0..model.cfg.layers {
                for p in 0..cache_b.len {
                    assert_eq!(
                        cache_b.k_row(layer, p),
                        ref_b.k_row(layer, p),
                        "{fam:?} quantized={quantized}: K layer {layer} pos {p}"
                    );
                }
            }
        }
    }
}

#[test]
fn scratch_reuse_across_ragged_shapes_is_invisible() {
    // Grow the workspace on a wide call, then run narrower and wider
    // calls through the same workspace — results must be bitwise those
    // of fresh-workspace calls (buffer contents never leak through).
    let model = tiny(Family::Opt, 85);
    let bm = BackendModel::dense(&model);
    let shapes: [&[&[u32]]; 3] = [
        &[&[1, 2, 3, 4, 5, 6, 7], &[8, 9, 10], &[11, 12]],
        &[&[13]],
        &[&[14, 15], &[16, 17, 18, 19]],
    ];
    let mut reused = ForwardScratch::new();
    let mut caches_reused: Vec<KvCache> = (0..4).map(|_| KvCache::new(&model.cfg)).collect();
    let mut caches_fresh: Vec<KvCache> = (0..4).map(|_| KvCache::new(&model.cfg)).collect();
    for chunks in shapes {
        let nb = chunks.len();
        let mut refs_r: Vec<&mut KvCache> = caches_reused.iter_mut().take(nb).collect();
        let out_r = bm.forward_chunks_refs_with(chunks, &mut refs_r, &mut reused);
        let mut refs_f: Vec<&mut KvCache> = caches_fresh.iter_mut().take(nb).collect();
        let out_f = bm.forward_chunks_refs(chunks, &mut refs_f);
        assert_eq!(out_r, out_f, "workspace reuse changed logits (batch {nb})");
    }
}

#[test]
fn prefill_chunked_stays_bitwise_on_head_major_cache() {
    // the historical pin, re-run over the new layout for every family:
    // chunked prefill == sequential decode, logits and cache state
    for fam in [Family::Opt, Family::Llama, Family::Bloom] {
        let model = tiny(fam, 87);
        let bm = BackendModel::dense(&model);
        let prompt: Vec<u32> = (0..23u32).map(|i| 2 + (5 * i) % 60).collect();
        let mut seq_cache = KvCache::new(&model.cfg);
        let mut seq_logits = Vec::new();
        for &t in &prompt {
            seq_logits = bm.decode_step(t, &mut seq_cache);
        }
        for chunk in [1usize, 4, 23] {
            let mut cache = KvCache::new(&model.cfg);
            let logits = bm.prefill_chunked(&prompt, &mut cache, chunk);
            assert_eq!(logits, seq_logits, "{fam:?} chunk {chunk}");
            for layer in 0..model.cfg.layers {
                assert_eq!(
                    cache.k_row(layer, prompt.len() - 1),
                    seq_cache.k_row(layer, prompt.len() - 1),
                    "{fam:?} chunk {chunk}: last K row"
                );
            }
        }
    }
}
