//! Fast-vs-Exact numerics tolerance harness for the two-tier contract.
//!
//! The `Fast` tier trades the Exact tier's bitwise-pinned arithmetic
//! for FMA contraction, polynomial `exp`, and fused online-softmax
//! attention. Its accuracy contract is *tolerance*, not identity, and
//! this suite pins that contract per kernel over a seeded sweep of
//! ragged shapes (dims drawn from 1..=1031, LUT planes 2/3, batch
//! 1/3/8 — the same alignment-hostile territory as `simd_parity.rs`).
//!
//! Budgets are per kernel, stated as relative error with a magnitude
//! guard (`|a−b| ≤ tol·(1 + max|a|,|b|)`; 1 ulp ≈ 1.2e-7 relative):
//!
//! * gemv/gemm (all three formats): `1e-4` — one fused rounding per
//!   multiply, same pinned accumulator tree, so error ~ n·ε over the
//!   1031-wide rows.
//! * activations (silu `1e-5`, gelu `1e-4`, softmax `1e-4`) — the
//!   polynomial `exp_fast` is within `1e-5` relative of libm.
//! * attention row: `2e-4` — online-softmax rescaling stacks a couple
//!   of extra roundings on top of the exp budget.
//!
//! The second half is the **Exact-mode regression pin**: dispatching
//! through `gemv_mode`/`gemm_mode` with [`NumericsMode::Exact`] must be
//! *bitwise* the legacy `gemv`/`gemm` path — the existing parity suites
//! (`simd_parity.rs`, `kernel_parity.rs`, `attn_parity.rs`) stay green
//! untouched because Exact is untouched.

use gptqt::kernels::fast_math::{
    attn_row_fast, axpy_fast, axpy_fast_scalar, dot_fast, dot_fast_scalar, exp_map_fast,
    exp_map_fast_scalar, gelu_map_fast, silu_mul_fast, softmax_fast,
};
use gptqt::kernels::{attn, simd, DenseGemv, Gemv, NumericsMode};
use gptqt::model::forward::softmax;
use gptqt::quant::linear::{rtn_quantize, IntLayer};
use gptqt::quant::pack::PackedBcLayer;
use gptqt::tensor::Tensor;
use gptqt::util::Rng;

const BATCHES: [usize; 3] = [1, 3, 8];
const GEMV_TOL: f32 = 1e-4;

/// Relative closeness with a magnitude guard (fast_math's `close`).
fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Seeded ragged sweep: corner shapes off every alignment (SIMD width
/// 8, GROUP 8) plus draws from the full 1..=1031 range.
fn ragged_shapes(rng: &mut Rng) -> Vec<(usize, usize)> {
    let mut shapes = vec![(33, 1031), (7, 129), (1, 9), (1031, 1)];
    for _ in 0..4 {
        shapes.push((rng.below(96) as usize + 1, rng.below(1031) as usize + 1));
    }
    shapes
}

fn random_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

fn as_refs(xs: &[Vec<f32>]) -> Vec<&[f32]> {
    xs.iter().map(|v| v.as_slice()).collect()
}

/// Every weight format the engine serves, over one ragged shape.
fn layers_for(rows: usize, cols: usize, rng: &mut Rng) -> Vec<(String, Box<dyn Gemv>)> {
    let w = Tensor::randn(rows, cols, 1.0, rng);
    let mut layers: Vec<(String, Box<dyn Gemv>)> =
        vec![("dense".into(), Box::new(DenseGemv::new(w.clone())))];
    for bits in [2u32, 3] {
        let (q, grids) = rtn_quantize(&w, bits);
        layers.push((format!("dequant{bits}"), Box::new(IntLayer::encode(&q, &grids, bits))));
    }
    for planes in [2usize, 3] {
        layers.push((
            format!("lut{planes}"),
            Box::new(PackedBcLayer::random(rows, cols, planes, rows as u64 + planes as u64)),
        ));
    }
    layers
}

#[test]
fn fast_gemv_tracks_exact_within_budget_on_ragged_shapes() {
    let mut rng = Rng::new(9101);
    for (rows, cols) in ragged_shapes(&mut rng) {
        for (label, layer) in layers_for(rows, cols, &mut rng) {
            let x = random_vec(cols, &mut rng);
            let mut y_exact = vec![0.0f32; rows];
            let mut y_fast = vec![0.0f32; rows];
            layer.gemv_mode(&x, &mut y_exact, NumericsMode::Exact);
            layer.gemv_mode(&x, &mut y_fast, NumericsMode::Fast);
            for r in 0..rows {
                assert!(
                    close(y_exact[r], y_fast[r], GEMV_TOL),
                    "{label} {rows}x{cols} row {r}: exact={} fast={}",
                    y_exact[r],
                    y_fast[r]
                );
            }
            for &batch in &BATCHES {
                let xs: Vec<Vec<f32>> = (0..batch).map(|_| random_vec(cols, &mut rng)).collect();
                let refs = as_refs(&xs);
                let mut ys_exact: Vec<Vec<f32>> = (0..batch).map(|_| vec![0.0; rows]).collect();
                let mut ys_fast = ys_exact.clone();
                layer.gemm_mode(&refs, &mut ys_exact, NumericsMode::Exact);
                layer.gemm_mode(&refs, &mut ys_fast, NumericsMode::Fast);
                for b in 0..batch {
                    // tolerance vs Exact...
                    for r in 0..rows {
                        assert!(
                            close(ys_exact[b][r], ys_fast[b][r], GEMV_TOL),
                            "{label} {rows}x{cols} B={batch} item {b} row {r}"
                        );
                    }
                    // ...and the per-mode determinism pin: batched Fast
                    // must be bitwise the single-item Fast gemv (the
                    // batched == sequential token guarantee, per mode)
                    let mut single = vec![0.0f32; rows];
                    layer.gemv_mode(&xs[b], &mut single, NumericsMode::Fast);
                    assert_eq!(
                        single, ys_fast[b],
                        "{label} {rows}x{cols} B={batch} item {b}: fast gemm != gemv"
                    );
                }
            }
        }
    }
}

#[test]
fn fast_activations_track_exact_within_budget() {
    let mut rng = Rng::new(9102);
    for _ in 0..6 {
        let n = rng.below(1031) as usize + 1;
        let gate = random_vec(n, &mut rng).iter().map(|v| v * 3.0).collect::<Vec<_>>();
        let up = random_vec(n, &mut rng);

        let mut g_exact = gate.clone();
        simd::silu_mul(&mut g_exact, &up);
        let mut g_fast = gate.clone();
        silu_mul_fast(&mut g_fast, &up);
        for i in 0..n {
            assert!(close(g_exact[i], g_fast[i], 1e-5), "silu n={n} i={i}");
        }

        let mut u_exact = gate.clone();
        simd::gelu_map(&mut u_exact);
        let mut u_fast = gate.clone();
        gelu_map_fast(&mut u_fast);
        for i in 0..n {
            assert!(close(u_exact[i], u_fast[i], 1e-4), "gelu n={n} i={i}");
        }

        let mut s_exact = gate.clone();
        softmax(&mut s_exact);
        let mut s_fast = gate.clone();
        softmax_fast(&mut s_fast);
        let sum: f32 = s_fast.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "softmax n={n} sum={sum}");
        for i in 0..n {
            assert!(close(s_exact[i], s_fast[i], 1e-4), "softmax n={n} i={i}");
        }
    }
}

#[test]
fn fast_attention_row_tracks_exact_pipeline_on_ragged_contexts() {
    let mut rng = Rng::new(9103);
    // dh off the vector width, ctx crossing ATTN_BLOCK boundaries and
    // reaching the full 1..=1031 sweep range
    for &dh in &[3usize, 8, 61] {
        for _ in 0..3 {
            let ctx = rng.below(1031) as usize + 1;
            let q = random_vec(dh, &mut rng);
            let kstrip = random_vec(ctx * dh, &mut rng);
            let vstrip = random_vec(ctx * dh, &mut rng);
            let scale = 1.0 / (dh as f32).sqrt();
            for slope in [0.0f32, -0.0625] {
                let mut scores = vec![0.0f32; ctx];
                attn::qk_dots(&q, &kstrip, scale, slope, ctx - 1, &mut scores);
                softmax(&mut scores);
                let mut want = vec![0.0f32; dh];
                attn::av_accumulate(&scores, &vstrip, &mut want);

                let mut got = vec![0.0f32; dh];
                attn_row_fast(&q, &kstrip, &vstrip, scale, slope, ctx - 1, &mut got);
                for d in 0..dh {
                    assert!(
                        close(want[d], got[d], 2e-4),
                        "dh={dh} ctx={ctx} slope={slope} d={d}: exact={} fast={}",
                        want[d],
                        got[d]
                    );
                }
            }
        }
    }
}

#[test]
fn exact_mode_dispatch_is_bitwise_the_legacy_path() {
    let mut rng = Rng::new(9104);
    for (rows, cols) in ragged_shapes(&mut rng) {
        for (label, layer) in layers_for(rows, cols, &mut rng) {
            let x = random_vec(cols, &mut rng);
            let mut y_legacy = vec![0.0f32; rows];
            let mut y_mode = vec![0.0f32; rows];
            layer.gemv(&x, &mut y_legacy);
            layer.gemv_mode(&x, &mut y_mode, NumericsMode::Exact);
            assert_eq!(y_legacy, y_mode, "{label} {rows}x{cols}: Exact dispatch drifted");
            for &batch in &BATCHES {
                let xs: Vec<Vec<f32>> = (0..batch).map(|_| random_vec(cols, &mut rng)).collect();
                let refs = as_refs(&xs);
                let mut ys_legacy: Vec<Vec<f32>> = (0..batch).map(|_| vec![0.0; rows]).collect();
                let mut ys_mode = ys_legacy.clone();
                layer.gemm(&refs, &mut ys_legacy);
                layer.gemm_mode(&refs, &mut ys_mode, NumericsMode::Exact);
                assert_eq!(ys_legacy, ys_mode, "{label} {rows}x{cols} B={batch}");
            }
        }
    }
}

#[test]
fn fast_scalar_twins_match_dispatched_fast_kernels_bitwise() {
    // Fast's determinism contract: the mul_add scalar twins are the
    // bitwise reference for the AVX2+FMA dispatch, so `to_bits`
    // equality — not a tolerance — is the right check here.
    let mut rng = Rng::new(9301);
    for n in [1usize, 7, 8, 9, 64, 129, 1031] {
        let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        assert_eq!(
            dot_fast(&a, &b).to_bits(),
            dot_fast_scalar(&a, &b).to_bits(),
            "dot_fast n={n}"
        );
        let mut acc_s = a.clone();
        let mut acc_d = a.clone();
        axpy_fast_scalar(&mut acc_s, -0.375, &b);
        axpy_fast(&mut acc_d, -0.375, &b);
        assert_eq!(acc_s, acc_d, "axpy_fast n={n}");
        let mut e_s = b.clone();
        let mut e_d = b.clone();
        exp_map_fast_scalar(&mut e_s);
        exp_map_fast(&mut e_d);
        for (i, (u, v)) in e_s.iter().zip(&e_d).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "exp_map_fast n={n} i={i}");
        }
    }
}
