//! Kernel micro-benchmarks: the three weight-format matvecs underneath
//! Table IV, isolated from the model. Shows where the LUT-GEMM win comes
//! from (bytes streamed, not flops), and races the runtime-dispatched
//! SIMD tier against the pinned scalar tier on the batched kernels.
//!
//! `--smoke` runs the CI profile: tiny dims, minimal iterations,
//! deterministic seeds — plus the SIMD-vs-scalar headline at
//! 4096×4096×3 planes, batch 8 — and always writes the machine-readable
//! `BENCH_kernels.json` (`{name, tokens_per_sec, ns_per_call,
//! simd_tier, numerics}` entries) that the bench-smoke CI job uploads
//! as the perf-trajectory artifact. The attention sweep additionally
//! races the Fast numerics tier (fused FMA online-softmax row) against
//! the Exact pipeline.

use gptqt::bench::{write_bench_json, BenchRecord, Suite};
use gptqt::kernels::attn::{av_accumulate, av_accumulate_scalar, qk_dots, qk_dots_scalar};
use gptqt::kernels::fast_math::attn_row_fast;
use gptqt::kernels::gemv_lut::gemm_lut_scalar;
use gptqt::kernels::{gemv_f32, simd, Gemv, NumericsMode};
use gptqt::model::forward::softmax;
use gptqt::quant::linear::{rtn_quantize, IntLayer};
use gptqt::quant::pack::PackedBcLayer;
use gptqt::tensor::Tensor;
use gptqt::util::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (warmup, iters) = if smoke { (1, 2) } else { (3, 30) };
    let mut rng = Rng::new(1);
    let mut records: Vec<BenchRecord> = Vec::new();
    println!("simd tier: {}", simd::tier().label());

    let gemv_shapes: &[(usize, usize)] = if smoke {
        &[(64, 64), (96, 256)]
    } else {
        &[(512, 512), (1024, 1024), (2048, 2048), (2048, 8192)]
    };
    let mut suite = Suite::new("weight-format matvec kernels");
    for &(rows, cols) in gemv_shapes {
        let w = Tensor::randn(rows, cols, 0.02, &mut rng);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0f32; rows];

        let label = format!("{rows}x{cols}");
        let r = suite.run(&format!("gemv_f32      {label}"), warmup, iters, || {
            gemv_f32(&w, &x, &mut y);
            std::hint::black_box(&y);
        });
        records.push(r.to_record(1.0));

        let (q, grids) = rtn_quantize(&w, 2);
        let il = IntLayer::encode(&q, &grids, 2);
        let r = suite.run(&format!("gemv_dequant2 {label}"), warmup, iters, || {
            il.gemv(&x, &mut y);
            std::hint::black_box(&y);
        });
        records.push(r.to_record(1.0));

        let packed = PackedBcLayer::random(rows, cols, 3, rows as u64);
        let r = suite.run(&format!("gemv_lut3     {label}"), warmup, iters, || {
            packed.gemv(&x, &mut y);
            std::hint::black_box(&y);
        });
        records.push(r.to_record(1.0));

        println!(
            "  bytes/matvec: f32 {:.2} MB | int2 {:.2} MB | lut3 {:.2} MB",
            (rows * cols * 4) as f64 / 1e6,
            il.streamed_bytes() as f64 / 1e6,
            packed.streamed_bytes() as f64 / 1e6,
        );
        if let Some(r) = suite.ratio(
            &format!("gemv_f32      {label}"),
            &format!("gemv_lut3     {label}"),
        ) {
            println!("  speedup lut3 vs f32 at {label}: {r:.2}x");
        }
    }

    // ---- batched gemm: weight streaming amortized across B activations
    let (rows, cols) = if smoke { (128usize, 128usize) } else { (1024usize, 1024usize) };
    let mut suite = Suite::new(&format!("batched gemm weight reuse ({rows}x{cols})"));
    let w = Tensor::randn(rows, cols, 0.02, &mut rng);
    let dense = gptqt::kernels::DenseGemv::new(w.clone());
    let (q, grids) = rtn_quantize(&w, 2);
    let il = IntLayer::encode(&q, &grids, 2);
    let packed = PackedBcLayer::random(rows, cols, 3, 2);
    let batches: &[usize] = if smoke { &[1, 8] } else { &[1, 4, 16] };
    for &batch in batches {
        let xs: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
            .collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut ys: Vec<Vec<f32>> = (0..batch).map(|_| vec![0.0f32; rows]).collect();
        for (label, layer) in [
            ("gemm_f32     ", &dense as &dyn Gemv),
            ("gemm_dequant2", &il as &dyn Gemv),
            ("gemm_lut3    ", &packed as &dyn Gemv),
        ] {
            let r = suite.run(&format!("{label} B={batch:<2}"), warmup.max(1), iters, || {
                layer.gemm(&refs, &mut ys);
                std::hint::black_box(&ys);
            });
            let per_tok_ns = r.median_ns / batch as f64;
            records.push(r.to_record(batch as f64));
            println!(
                "  {label} B={batch:<2}: {per_tok_ns:>10.0} ns/token, \
                 {:.3} MB weight traffic/token (amortized)",
                layer.streamed_bytes() as f64 / batch as f64 / 1e6,
            );
        }
    }

    // ---- SIMD-vs-scalar headline: the acceptance shape for the AVX2
    // inner loops — gemm_lut at 4096×4096, planes 3, batch 8. Runs in
    // both modes (the smoke JSON is where CI reads the ratio from).
    let (rows, cols, planes, batch) = (4096usize, 4096usize, 3usize, 8usize);
    let mut suite = Suite::new(&format!(
        "gemm_lut{planes} {rows}x{cols} B={batch}: {} vs scalar tier",
        simd::tier().label()
    ));
    let packed = PackedBcLayer::random(rows, cols, planes, 4096);
    let xs: Vec<Vec<f32>> = (0..batch)
        .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
        .collect();
    let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
    let mut ys: Vec<Vec<f32>> = (0..batch).map(|_| vec![0.0f32; rows]).collect();
    let (hw, hi) = if smoke { (1, 3) } else { (2, 10) };
    let dispatched_name =
        format!("gemm_lut{planes} {rows}x{cols} B={batch} {}", simd::tier().label());
    let r = suite.run(&dispatched_name, hw, hi, || {
        packed.gemm(&refs, &mut ys);
        std::hint::black_box(&ys);
    });
    records.push(r.to_record(batch as f64));
    let scalar_name = format!("gemm_lut{planes} {rows}x{cols} B={batch} scalar");
    let r = suite.run(&scalar_name, hw, hi, || {
        gemm_lut_scalar(&packed, &refs, &mut ys);
        std::hint::black_box(&ys);
    });
    records.push(r.to_record(batch as f64));
    if let Some(ratio) = suite.ratio(&scalar_name, &dispatched_name) {
        println!(
            "  {} vs scalar at {rows}x{cols}x{planes} B={batch}: {ratio:.2}x",
            simd::tier().label()
        );
    }

    // ---- attention kernels: one decode row's (row, head) items over
    // head-major strips — qk_dots + softmax + av_accumulate per head,
    // dispatched vs pinned-scalar tier, context sweep. The bench-trend
    // job tracks these records for attention regressions; the ratio is
    // the acceptance line (dispatched must win from ctx ≥ 512). The
    // third entrant is the Fast numerics tier's fused online-softmax
    // kernel (attn_row_fast), raced against the Exact pipeline at the
    // same shapes — its records are tagged "numerics": "fast".
    let (heads, dh) = (8usize, 64usize);
    let d_model = heads * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    for &ctx in &[128usize, 512, 2048] {
        let mut suite = Suite::new(&format!(
            "attention row ctx={ctx} heads={heads} dh={dh}: {} vs scalar tier",
            simd::tier().label()
        ));
        let q: Vec<f32> = (0..d_model).map(|_| rng.normal_f32()).collect();
        let kstrips: Vec<Vec<f32>> = (0..heads)
            .map(|_| (0..ctx * dh).map(|_| rng.normal_f32()).collect())
            .collect();
        let vstrips: Vec<Vec<f32>> = (0..heads)
            .map(|_| (0..ctx * dh).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut scores = vec![0.0f32; ctx];
        let mut out = vec![0.0f32; d_model];
        let (aw, ai) = if smoke { (1, 4) } else { (3, 20) };
        let disp_name = format!("attn row ctx={ctx} h={heads} dh={dh} {}", simd::tier().label());
        let r = suite.run(&disp_name, aw, ai, || {
            out.fill(0.0);
            for h in 0..heads {
                let qh = &q[h * dh..(h + 1) * dh];
                qk_dots(qh, &kstrips[h], scale, 0.0, ctx - 1, &mut scores);
                softmax(&mut scores);
                av_accumulate(&scores, &vstrips[h], &mut out[h * dh..(h + 1) * dh]);
            }
            std::hint::black_box(&out);
        });
        records.push(r.to_record(ctx as f64));
        let scalar_name = format!("attn row ctx={ctx} h={heads} dh={dh} scalar");
        let r = suite.run(&scalar_name, aw, ai, || {
            out.fill(0.0);
            for h in 0..heads {
                let qh = &q[h * dh..(h + 1) * dh];
                qk_dots_scalar(qh, &kstrips[h], scale, 0.0, ctx - 1, &mut scores);
                softmax(&mut scores);
                av_accumulate_scalar(&scores, &vstrips[h], &mut out[h * dh..(h + 1) * dh]);
            }
            std::hint::black_box(&out);
        });
        records.push(r.to_record(ctx as f64));
        if let Some(ratio) = suite.ratio(&scalar_name, &disp_name) {
            println!(
                "  attention {} vs scalar at ctx={ctx}: {ratio:.2}x",
                simd::tier().label()
            );
        }
        // Fast tier: one fused flash-style call per head, no score buffer
        let fast_name = format!("attn row ctx={ctx} h={heads} dh={dh} fast");
        let r = suite.run(&fast_name, aw, ai, || {
            for h in 0..heads {
                let qh = &q[h * dh..(h + 1) * dh];
                attn_row_fast(
                    qh,
                    &kstrips[h],
                    &vstrips[h],
                    scale,
                    0.0,
                    ctx - 1,
                    &mut out[h * dh..(h + 1) * dh],
                );
            }
            std::hint::black_box(&out);
        });
        records.push(r.to_record_mode(ctx as f64, NumericsMode::Fast));
        if let Some(ratio) = suite.ratio(&disp_name, &fast_name) {
            println!("  attention fast vs exact at ctx={ctx}: {ratio:.2}x");
        }
    }

    write_bench_json("BENCH_kernels.json", &records).expect("write BENCH_kernels.json");
    println!("\nwrote BENCH_kernels.json ({} records)", records.len());
}
