//! Kernel micro-benchmarks: the three weight-format matvecs underneath
//! Table IV, isolated from the model. Shows where the LUT-GEMM win comes
//! from (bytes streamed, not flops).

use gptqt::bench::Suite;
use gptqt::kernels::{gemv_f32, Gemv};
use gptqt::quant::fuse::FusedRow;
use gptqt::quant::linear::{rtn_quantize, IntLayer};
use gptqt::quant::pack::PackedBcLayer;
use gptqt::tensor::Tensor;
use gptqt::util::Rng;

fn random_packed(rows: usize, cols: usize, planes: usize, rng: &mut Rng) -> PackedBcLayer {
    let fused: Vec<FusedRow> = (0..rows)
        .map(|_| FusedRow {
            alphas: (0..planes).map(|p| 0.02 / (1 << p) as f32).collect(),
            bias: 0.001,
        })
        .collect();
    let patterns: Vec<Vec<u32>> = (0..rows)
        .map(|_| (0..cols).map(|_| rng.below(1 << planes) as u32).collect())
        .collect();
    PackedBcLayer::pack(rows, cols, &fused, &patterns)
}

fn main() {
    let mut rng = Rng::new(1);
    let mut suite = Suite::new("weight-format matvec kernels");
    for &(rows, cols) in &[(512usize, 512usize), (1024, 1024), (2048, 2048), (2048, 8192)] {
        let w = Tensor::randn(rows, cols, 0.02, &mut rng);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0f32; rows];

        let label = format!("{rows}x{cols}");
        suite.run(&format!("gemv_f32      {label}"), 3, 30, || {
            gemv_f32(&w, &x, &mut y);
            std::hint::black_box(&y);
        });

        let (q, grids) = rtn_quantize(&w, 2);
        let il = IntLayer::encode(&q, &grids, 2);
        suite.run(&format!("gemv_dequant2 {label}"), 3, 30, || {
            il.gemv(&x, &mut y);
            std::hint::black_box(&y);
        });

        let packed = random_packed(rows, cols, 3, &mut rng);
        suite.run(&format!("gemv_lut3     {label}"), 3, 30, || {
            packed.gemv(&x, &mut y);
            std::hint::black_box(&y);
        });

        println!(
            "  bytes/matvec: f32 {:.2} MB | int2 {:.2} MB | lut3 {:.2} MB",
            (rows * cols * 4) as f64 / 1e6,
            il.streamed_bytes() as f64 / 1e6,
            packed.streamed_bytes() as f64 / 1e6,
        );
        if let Some(r) = suite.ratio(
            &format!("gemv_f32      {label}"),
            &format!("gemv_lut3     {label}"),
        ) {
            println!("  speedup lut3 vs f32 at {label}: {r:.2}x");
        }
    }
}
