//! Kernel micro-benchmarks: the three weight-format matvecs underneath
//! Table IV, isolated from the model. Shows where the LUT-GEMM win comes
//! from (bytes streamed, not flops).

use gptqt::bench::Suite;
use gptqt::kernels::{gemv_f32, Gemv};
use gptqt::quant::linear::{rtn_quantize, IntLayer};
use gptqt::quant::pack::PackedBcLayer;
use gptqt::tensor::Tensor;
use gptqt::util::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let mut suite = Suite::new("weight-format matvec kernels");
    for &(rows, cols) in &[(512usize, 512usize), (1024, 1024), (2048, 2048), (2048, 8192)] {
        let w = Tensor::randn(rows, cols, 0.02, &mut rng);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0f32; rows];

        let label = format!("{rows}x{cols}");
        suite.run(&format!("gemv_f32      {label}"), 3, 30, || {
            gemv_f32(&w, &x, &mut y);
            std::hint::black_box(&y);
        });

        let (q, grids) = rtn_quantize(&w, 2);
        let il = IntLayer::encode(&q, &grids, 2);
        suite.run(&format!("gemv_dequant2 {label}"), 3, 30, || {
            il.gemv(&x, &mut y);
            std::hint::black_box(&y);
        });

        let packed = PackedBcLayer::random(rows, cols, 3, rows as u64);
        suite.run(&format!("gemv_lut3     {label}"), 3, 30, || {
            packed.gemv(&x, &mut y);
            std::hint::black_box(&y);
        });

        println!(
            "  bytes/matvec: f32 {:.2} MB | int2 {:.2} MB | lut3 {:.2} MB",
            (rows * cols * 4) as f64 / 1e6,
            il.streamed_bytes() as f64 / 1e6,
            packed.streamed_bytes() as f64 / 1e6,
        );
        if let Some(r) = suite.ratio(
            &format!("gemv_f32      {label}"),
            &format!("gemv_lut3     {label}"),
        ) {
            println!("  speedup lut3 vs f32 at {label}: {r:.2}x");
        }
    }

    // ---- batched gemm: weight streaming amortized across B activations
    let mut suite = Suite::new("batched gemm weight reuse (1024x1024)");
    let (rows, cols) = (1024usize, 1024usize);
    let w = Tensor::randn(rows, cols, 0.02, &mut rng);
    let dense = gptqt::kernels::DenseGemv::new(w.clone());
    let (q, grids) = rtn_quantize(&w, 2);
    let il = IntLayer::encode(&q, &grids, 2);
    let packed = PackedBcLayer::random(rows, cols, 3, 2);
    for &batch in &[1usize, 4, 16] {
        let xs: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..cols).map(|_| rng.normal_f32()).collect())
            .collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut ys: Vec<Vec<f32>> = (0..batch).map(|_| vec![0.0f32; rows]).collect();
        for (label, layer) in [
            ("gemm_f32     ", &dense as &dyn Gemv),
            ("gemm_dequant2", &il as &dyn Gemv),
            ("gemm_lut3    ", &packed as &dyn Gemv),
        ] {
            let r = suite.run(&format!("{label} B={batch:<2}"), 2, 15, || {
                layer.gemm(&refs, &mut ys);
                std::hint::black_box(&ys);
            });
            let per_tok_ns = r.median_ns / batch as f64;
            println!(
                "  {label} B={batch:<2}: {per_tok_ns:>10.0} ns/token, \
                 {:.3} MB weight traffic/token (amortized)",
                layer.streamed_bytes() as f64 / batch as f64 / 1e6,
            );
        }
    }
}
