//! Table IV end-to-end: per-token decode latency of the full model under
//! the three weight formats, across the OPT ladder (trained weights not
//! required — timing only), plus the batched-serving sweep: tokens/sec
//! at batch {1, 4, 16} per format with the amortized weight traffic.
//! `gptqt exp table4` prints the batch-1 numbers with table formatting.
//!
//! The prefill sweep at the end compares the chunk-major multi-token
//! prefill against the legacy per-token loop over prompt ∈ {64, 256,
//! 1024, 2048} × batch ∈ {1, 8}, reporting prefill tokens/sec and TTFT
//! — the trajectory line for the chunking win, the SIMD inner loops,
//! and (at the 1024+ points) the vectorized head-major attention
//! subsystem.
//!
//! The prefix-cache section serves one prompt twice through an engine
//! (cache enabled) and records the named `serve prefix cold` /
//! `serve prefix_hit` TTFT entries — the trend pair for the
//! prefix-reuse win.
//!
//! The streaming-serve section races the two numerics tiers: every
//! `serve stream` point is measured once per [`NumericsMode`] (engine
//! configured via `EngineConfig::numerics`), so BENCH_speed.json holds
//! an `exact`/`fast` pair per policy — the trend line for the Fast
//! kernel tier's end-to-end win.
//!
//! The speculative-serve section drives the draft/verify protocol
//! end-to-end for the two pairs GPTQT gets for free (`lut2->lut3`,
//! `lut2->dense`): each `serve spec` record carries effective
//! tokens/sec *and* the acceptance rate (`acceptance_rate` key, only
//! present on these records) — the trend pair for the speculative
//! decoding win, diffed by bench_trend.py alongside the timing keys.
//!
//! `--fast` shrinks the ladder; `--smoke` is the CI profile (opt-nano
//! only, a handful of tokens, deterministic seeds) and is what the
//! bench-smoke job runs. Both normal and smoke runs write the
//! machine-readable `BENCH_speed.json` (`{name, tokens_per_sec,
//! ns_per_call, simd_tier, numerics}`) uploaded as a CI artifact.

use gptqt::bench::{write_bench_json, BenchRecord};
use gptqt::coordinator::SchedulePolicyKind;
use gptqt::eval::speed::{
    build_variant, measure_decode, measure_decode_batch, measure_prefill, measure_prefix_ttft,
    measure_spec_streaming, measure_streaming, SpeedVariant,
};
use gptqt::kernels::NumericsMode;
use gptqt::model::init::random_weights;
use gptqt::model::{load_or_init, presets, Model};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let fast = smoke || std::env::args().any(|a| a == "--fast");
    let mut records: Vec<BenchRecord> = Vec::new();
    println!("simd tier: {}", gptqt::kernels::simd::tier().label());

    let ladder: Vec<&str> = if smoke {
        vec!["opt-nano"]
    } else if fast {
        vec!["opt-nano", "opt-mini"]
    } else {
        vec!["opt-nano", "opt-mini", "opt-sm", "opt-md", "opt-lg"]
    };
    let gen_tokens = if smoke {
        4
    } else if fast {
        8
    } else {
        24
    };
    println!("\n=== bench suite: Table IV — ms/token, batch 1 (gen {gen_tokens} tokens) ===");
    println!(
        "{:<12} {:>10} {:>14} {:>14} {:>14} {:>9}",
        "model", "params", "full fp32", "GPTQ2 dequant", "GPTQT3 LUT", "speedup"
    );
    for name in &ladder {
        let (model, _) = load_or_init(name, "artifacts", 0).expect("preset");
        let mut ms = Vec::new();
        for variant in [
            SpeedVariant::Full,
            SpeedVariant::GptqInt { bits: 2 },
            SpeedVariant::GptqtLut { bits: 3 },
        ] {
            let bm = build_variant(&model, variant, 0);
            let r = measure_decode(&model.cfg, &bm, variant, 8, gen_tokens, 7);
            records.push(BenchRecord::new(
                format!("decode {} {} B=1", name, variant.label()),
                1e3 / r.ms_per_token.max(1e-12),
                r.ms_per_token * 1e6,
            ));
            ms.push(r.ms_per_token);
        }
        println!(
            "{:<12} {:>10} {:>11.2} ms {:>11.2} ms {:>11.2} ms {:>8.2}x",
            name,
            presets::by_name(name)
                .map(|c| gptqt::model::fmt_params(c.param_count()))
                .unwrap_or_default(),
            ms[0],
            ms[1],
            ms[2],
            ms[0] / ms[2],
        );
    }

    // ---- batched decode: weight reuse across concurrent sequences -----
    let batch_ladder: Vec<&str> = if fast {
        vec!["opt-nano"]
    } else {
        vec!["opt-mini", "opt-sm"]
    };
    let gen_steps = if smoke {
        3
    } else if fast {
        6
    } else {
        16
    };
    let batches: &[usize] = if smoke { &[1, 8] } else { &[1, 4, 16] };
    println!(
        "\n=== bench suite: batched decode — tokens/sec at batch {batches:?} \
         (gen {gen_steps} steps/seq) ==="
    );
    println!(
        "{:<12} {:<18} {:>6} {:>12} {:>14} {:>16}",
        "model", "format", "batch", "ms/step", "tok/s", "MB/token (amort)"
    );
    for name in &batch_ladder {
        let (model, _) = load_or_init(name, "artifacts", 0).expect("preset");
        for variant in [
            SpeedVariant::Full,
            SpeedVariant::GptqInt { bits: 2 },
            SpeedVariant::GptqtLut { bits: 3 },
        ] {
            let bm = build_variant(&model, variant, 0);
            let mut tps_first = 0.0f64;
            let mut tps_last = 0.0f64;
            for &batch in batches {
                let r = measure_decode_batch(&model.cfg, &bm, variant, batch, 8, gen_steps, 7);
                if batch == batches[0] {
                    tps_first = r.tokens_per_sec;
                }
                if batch == *batches.last().unwrap() {
                    tps_last = r.tokens_per_sec;
                }
                records.push(BenchRecord::new(
                    format!("decode_batch {} {} B={}", name, variant.label(), batch),
                    r.tokens_per_sec,
                    r.ms_per_step * 1e6,
                ));
                println!(
                    "{:<12} {:<18} {:>6} {:>12.3} {:>14.0} {:>16.3}",
                    name,
                    variant.label(),
                    batch,
                    r.ms_per_step,
                    r.tokens_per_sec,
                    r.amortized_mb_per_token,
                );
            }
            if tps_first > 0.0 && tps_last > 0.0 && batches.len() > 1 {
                println!(
                    "  -> {} batched B={} vs sequential B={} throughput: {:.2}x",
                    variant.label(),
                    batches.last().unwrap(),
                    batches[0],
                    tps_last / tps_first
                );
            }
        }
    }

    // ---- prefill: chunked multi-token forward vs per-token loop --------
    // Prompt lengths exceed the preset max_seq (256), so the sweep runs a
    // widened KV capacity with random weights (timing only).
    // The long-context points (1024+) are where the vectorized
    // head-major attention dominates the tick: the per-position QK/AV
    // loops are the O(prompt²) term chunked prefill cannot amortize
    // away, so this sweep is the trajectory line for the attention
    // subsystem (smoke keeps a 1024 point for the bench-trend job).
    let (prefill_model, chunk) = if fast { ("opt-nano", 16) } else { ("opt-sm", 32) };
    let prompt_lens: &[usize] = if smoke {
        &[32, 1024]
    } else if fast {
        &[64, 256, 1024]
    } else {
        &[64, 256, 1024, 2048]
    };
    let prefill_batches: &[usize] = if smoke { &[1, 4] } else { &[1, 8] };
    let mut cfg = presets::by_name(prefill_model).expect("preset");
    cfg.max_seq = prompt_lens.iter().copied().max().unwrap_or(256) + 32;
    let model = Model::new(cfg.clone(), random_weights(&cfg, 0));
    println!(
        "\n=== bench suite: prefill — chunked (chunk {chunk}) vs per-token loop \
         ({prefill_model}) ==="
    );
    println!(
        "{:<18} {:>7} {:>6} {:>15} {:>15} {:>11} {:>11} {:>9}",
        "format", "prompt", "batch", "tok/s chunked", "tok/s 1-tok", "ttft ms ck",
        "ttft ms 1t", "speedup"
    );
    for variant in [SpeedVariant::Full, SpeedVariant::GptqtLut { bits: 3 }] {
        let bm = build_variant(&model, variant, 0);
        for &plen in prompt_lens {
            for &batch in prefill_batches {
                let base = measure_prefill(&cfg, &bm, variant, batch, plen, 0, 7);
                let chunked = measure_prefill(&cfg, &bm, variant, batch, plen, chunk, 7);
                let pname =
                    format!("prefill {} p={plen} B={batch} chunk={chunk}", variant.label());
                records.push(BenchRecord::new(
                    pname,
                    chunked.tokens_per_sec,
                    (batch * plen) as f64 * 1e9 / chunked.tokens_per_sec.max(1e-12),
                ));
                println!(
                    "{:<18} {:>7} {:>6} {:>15.0} {:>15.0} {:>11.2} {:>11.2} {:>8.2}x",
                    variant.label(),
                    plen,
                    batch,
                    chunked.tokens_per_sec,
                    base.tokens_per_sec,
                    chunked.ttft_ms,
                    base.ttft_ms,
                    chunked.tokens_per_sec / base.tokens_per_sec.max(1e-12),
                );
            }
        }
    }

    // ---- streaming session server: client-observed TTFT + tok/s -------
    // The full serving stack (queue → engine thread → event channels),
    // per schedule policy — the number a deployment actually delivers.
    let (serve_model, n_reqs, s_gen) = if smoke {
        ("opt-nano", 4, 4)
    } else if fast {
        ("opt-nano", 8, 12)
    } else {
        ("opt-mini", 16, 24)
    };
    let (model, _) = load_or_init(serve_model, "artifacts", 0).expect("preset");
    println!("\n=== bench suite: streaming serve — {serve_model}, {n_reqs} requests ===");
    for (kind, klabel) in [
        (SchedulePolicyKind::Fixed, "fixed"),
        (SchedulePolicyKind::Adaptive, "adaptive"),
    ] {
        let variant = SpeedVariant::GptqtLut { bits: 3 };
        let mut tps = [0.0f64; 2];
        for (i, numerics) in [NumericsMode::Exact, NumericsMode::Fast].into_iter().enumerate() {
            let bm = build_variant(&model, variant, 0);
            let r =
                measure_streaming(&model.cfg, bm, variant, n_reqs, 8, s_gen, kind, numerics, 7);
            tps[i] = r.tokens_per_sec;
            records.push(
                BenchRecord::new(
                    format!(
                        "serve stream {serve_model} {} R={n_reqs} policy={klabel} {}",
                        variant.label(),
                        numerics.label()
                    ),
                    r.tokens_per_sec,
                    r.ttft_ms * 1e6,
                )
                .with_numerics(numerics)
                .with_robustness(r.robustness),
            );
            println!(
                "{:<10} {:<6} {:>10.0} tok/s   ttft {:>8.2} ms   inter-token {:>7.3} ms   \
                 ({} tokens)",
                klabel, numerics.label(), r.tokens_per_sec, r.ttft_ms, r.inter_token_ms, r.tokens,
            );
        }
        if tps[0] > 0.0 {
            println!("  -> fast vs exact throughput ({klabel}): {:.2}x", tps[1] / tps[0]);
        }
    }

    // ---- speculative serve: draft/verify effective throughput ----------
    // The two-step quantization's free draft model (2-bit binary coding)
    // proposes k tokens per round; the served target verifies them in
    // one chunk-major forward. Greedy output is token-identical to the
    // target-only `serve stream` runs above, so tokens/sec here divided
    // by the matching target-only number is the pure speculation win.
    let (sp_model, sp_reqs, sp_gen) = if smoke {
        ("opt-nano", 4, 6)
    } else if fast {
        ("opt-nano", 8, 12)
    } else {
        ("opt-mini", 16, 24)
    };
    let spec_k = 4usize;
    let (model, _) = load_or_init(sp_model, "artifacts", 0).expect("preset");
    println!(
        "\n=== bench suite: speculative serve — {sp_model}, {sp_reqs} requests, k={spec_k} ==="
    );
    for (target_variant, pair) in [
        (SpeedVariant::GptqtLut { bits: 3 }, "lut2->lut3"),
        (SpeedVariant::Full, "lut2->dense"),
    ] {
        let draft = build_variant(&model, SpeedVariant::GptqtLut { bits: 2 }, 0);
        let target = build_variant(&model, target_variant, 0);
        let r = measure_spec_streaming(
            &model.cfg,
            draft,
            target,
            pair,
            sp_reqs,
            8,
            sp_gen,
            spec_k,
            NumericsMode::Exact,
            7,
        );
        records.push(
            BenchRecord::new(
                format!("serve spec {sp_model} {pair} k={spec_k} R={sp_reqs}"),
                r.tokens_per_sec,
                1e9 / r.tokens_per_sec.max(1e-12),
            )
            .with_numerics(NumericsMode::Exact)
            .with_acceptance(r.acceptance_rate)
            .with_robustness(r.robustness),
        );
        println!(
            "{:<14} {:>10.0} tok/s   accept {:>5.3}   tok/round {:>5.2}   \
             (drafted {} accepted {} rolled_back {})",
            pair, r.tokens_per_sec, r.acceptance_rate, r.tokens_per_round, r.drafted, r.accepted,
            r.rolled_back,
        );
    }

    // ---- prefix cache: cold vs hit TTFT through the engine -------------
    // The same prompt served twice; the second admission adopts the
    // cached paged-KV blocks and computes only the unmatched tail, so
    // `serve prefix_hit` vs `serve prefix cold` is the trajectory pair
    // for the prefix-cache win.
    let (pc_model, pc_prompt, pc_gen) = if smoke {
        ("opt-nano", 24, 4)
    } else if fast {
        ("opt-nano", 64, 8)
    } else {
        ("opt-mini", 128, 16)
    };
    let (model, _) = load_or_init(pc_model, "artifacts", 0).expect("preset");
    println!(
        "\n=== bench suite: prefix cache — cold vs hit TTFT ({pc_model}, prompt {pc_prompt}) ==="
    );
    for variant in [SpeedVariant::Full, SpeedVariant::GptqtLut { bits: 3 }] {
        let bm = build_variant(&model, variant, 0);
        let r = measure_prefix_ttft(&model.cfg, bm, variant, pc_prompt, pc_gen, 7);
        records.push(
            BenchRecord::new(
                format!("serve prefix cold {pc_model} {}", variant.label()),
                pc_prompt as f64 * 1e3 / r.cold_ttft_ms.max(1e-9),
                r.cold_ttft_ms * 1e6,
            )
            .with_robustness(r.robustness),
        );
        records.push(
            BenchRecord::new(
                format!("serve prefix_hit {pc_model} {}", variant.label()),
                pc_prompt as f64 * 1e3 / r.hit_ttft_ms.max(1e-9),
                r.hit_ttft_ms * 1e6,
            )
            .with_robustness(r.robustness),
        );
        println!(
            "{:<18} cold ttft {:>8.2} ms ({:>4} prefill toks)   hit ttft {:>8.2} ms \
             ({:>2} prefill toks, hits {})",
            variant.label(),
            r.cold_ttft_ms,
            r.prefill_tokens_cold,
            r.hit_ttft_ms,
            r.prefill_tokens_hit,
            r.hits,
        );
    }

    write_bench_json("BENCH_speed.json", &records).expect("write BENCH_speed.json");
    println!("\nwrote BENCH_speed.json ({} records)", records.len());
}
