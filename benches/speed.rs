//! Table IV end-to-end: per-token decode latency of the full model under
//! the three weight formats, across the OPT ladder (trained weights not
//! required — timing only). This is the bench that regenerates the
//! paper's speed table; `gptqt exp table4` prints the same numbers with
//! table formatting.

use gptqt::eval::speed::{build_variant, measure_decode, SpeedVariant};
use gptqt::model::{load_or_init, presets};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let ladder: Vec<&str> = if fast {
        vec!["opt-nano", "opt-mini"]
    } else {
        vec!["opt-nano", "opt-mini", "opt-sm", "opt-md", "opt-lg"]
    };
    let gen_tokens = if fast { 8 } else { 24 };
    println!("\n=== bench suite: Table IV — ms/token, batch 1 (gen {gen_tokens} tokens) ===");
    println!(
        "{:<12} {:>10} {:>14} {:>14} {:>14} {:>9}",
        "model", "params", "full fp32", "GPTQ2 dequant", "GPTQT3 LUT", "speedup"
    );
    for name in ladder {
        let (model, _) = load_or_init(name, "artifacts", 0).expect("preset");
        let mut ms = Vec::new();
        for variant in [
            SpeedVariant::Full,
            SpeedVariant::GptqInt { bits: 2 },
            SpeedVariant::GptqtLut { bits: 3 },
        ] {
            let bm = build_variant(&model, variant, 0);
            let r = measure_decode(&model.cfg, &bm, variant, 8, gen_tokens, 7);
            ms.push(r.ms_per_token);
        }
        println!(
            "{:<12} {:>10} {:>11.2} ms {:>11.2} ms {:>11.2} ms {:>8.2}x",
            name,
            presets::by_name(name)
                .map(|c| gptqt::model::fmt_params(c.param_count()))
                .unwrap_or_default(),
            ms[0],
            ms[1],
            ms[2],
            ms[0] / ms[2],
        );
    }
}
