//! Quantization pipeline cost: wall-clock per layer for each method
//! (the paper quantizes OPT-66B on one A100 — the per-layer cost profile
//! shows where GPTQT's search overhead sits relative to the GPTQ loop).

use gptqt::bench::Suite;
use gptqt::quant::gptq::accumulate_hessian;
use gptqt::quant::{quantize_layer, Method, QuantConfig};
use gptqt::tensor::Tensor;
use gptqt::util::Rng;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut rng = Rng::new(3);
    let (rows, d, tokens) = if fast { (64, 64, 128) } else { (192, 192, 384) };
    let w = Tensor::randn(rows, d, 0.02, &mut rng);
    let acts = Tensor::randn(tokens, d, 1.0, &mut rng);
    let h = accumulate_hessian(&acts);
    let iters = if fast { 3 } else { 5 };

    let mut suite = Suite::new(&format!("quantize_layer cost ({rows}x{d}, {tokens} calib tokens)"));
    for (method, bits) in [
        (Method::Rtn, 3),
        (Method::Gptq, 3),
        (Method::GptqMinMse, 3),
        (Method::Bcq, 3),
        (Method::GptqBcq, 3),
        (Method::Gptqt, 3),
        (Method::Gptqt, 2),
    ] {
        let cfg = QuantConfig { explore_grid: 6, ..QuantConfig::with_bits(bits) };
        suite.run(&format!("{:<14} {bits}-bit", method.name()), 1, iters, || {
            let q = quantize_layer(&w, &h, method, &cfg).unwrap();
            std::hint::black_box(q.stats.weight_mse);
        });
    }
    // Hessian accumulation is the other big cost center
    suite.run("hessian accumulate", 1, iters, || {
        std::hint::black_box(accumulate_hessian(&acts).n);
    });

    if let Some(r) = suite.ratio("GPTQT          3-bit", "GPTQ           3-bit") {
        println!("  GPTQT search overhead vs GPTQ: {r:.2}x");
    }
}
