//! Coordinator benchmarks: engine throughput under continuous batching —
//! the serving-layer ablation (max_batch 1 vs 4 vs 8) plus queue and
//! paged-KV manager micro-costs. Shows the scheduling machinery is not
//! the bottleneck (the paper's latency story is weight bandwidth).

use gptqt::bench::Suite;
use gptqt::coordinator::{
    CpuBackend, Engine, EngineConfig, PagedKvManager, Request, RequestQueue, Server,
};
use gptqt::model::init::random_weights;
use gptqt::model::{presets, BackendModel, Model};
use gptqt::util::Rng;

fn tiny_model() -> Model {
    let mut cfg = presets::by_name("opt-nano").unwrap();
    cfg.vocab = 256;
    cfg.max_seq = 64;
    Model::new(cfg.clone(), random_weights(&cfg, 42))
}

fn main() {
    let mut suite = Suite::new("coordinator");

    // --- scheduling-machinery micro costs -----------------------------
    suite.run("queue push+pop (1k reqs)", 2, 20, || {
        let q = RequestQueue::new(2048);
        for id in 0..1000u64 {
            q.push(Request::new(id, vec![1, 2, 3], 8)).unwrap();
        }
        while q.try_pop().is_some() {}
    });

    suite.run("paged-kv admit/append/release (1k seqs)", 2, 20, || {
        let mut kv = PagedKvManager::new(4096, 16);
        for seq in 0..1000u64 {
            assert!(kv.admit(seq, 16, 48));
            for _ in 0..8 {
                kv.append_token(seq);
            }
            kv.release(seq);
        }
    });

    // --- end-to-end engine throughput vs batch size --------------------
    let model = tiny_model();
    let mut tok_per_sec = Vec::new();
    for &max_batch in &[1usize, 4, 8] {
        let name = format!("engine 12 reqs, max_batch={max_batch}");
        let r = suite.run(&name, 1, 5, || {
            let backend = CpuBackend(BackendModel::dense(&model));
            let mut engine = Engine::new(
                backend,
                EngineConfig { max_batch, total_blocks: 512, ..Default::default() },
            );
            let mut rng = Rng::new(1);
            for id in 0..12u64 {
                let prompt: Vec<u32> = (0..8).map(|_| 3 + rng.below(250) as u32).collect();
                engine.submit(Request::new(id, prompt, 12)).unwrap();
            }
            let out = engine.run_to_completion().unwrap();
            assert_eq!(out.len(), 12);
        });
        let toks = 12.0 * 12.0; // 12 reqs × 12 generated tokens
        tok_per_sec.push((max_batch, toks / r.median_secs()));
    }
    for (mb, tps) in tok_per_sec {
        println!("  max_batch={mb}: {tps:.0} generated tok/s");
    }

    // --- streaming session round-trip: Server thread + event channels --
    // vs the in-thread engine loop above; the delta is the session
    // machinery's overhead (it should be noise next to the model math)
    suite.run("server stream 12 reqs, max_batch=4", 1, 5, || {
        let backend = CpuBackend(BackendModel::dense(&model));
        let server = Server::spawn(
            backend,
            EngineConfig { max_batch: 4, total_blocks: 512, ..Default::default() },
        );
        let mut rng = Rng::new(1);
        let handles: Vec<_> = (0..12u64)
            .map(|id| {
                let prompt: Vec<u32> = (0..8).map(|_| 3 + rng.below(250) as u32).collect();
                server.submit(Request::new(id, prompt, 12))
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        server.shutdown();
    });
}
