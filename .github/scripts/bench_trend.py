#!/usr/bin/env python3
"""Diff two BENCH_*.json artifacts and flag tokens_per_sec regressions.

Usage: bench_trend.py PREVIOUS.json CURRENT.json [--threshold PCT]

Writes a markdown table to $GITHUB_STEP_SUMMARY (stdout when unset)
and emits GitHub `::warning::` annotations on stdout for entries whose
tokens_per_sec dropped by more than the threshold (default 10%).
Always exits 0 — the trend job is a non-blocking signal, not a gate
(smoke benches run on shared CI runners, so single-run noise is
expected; the trajectory across PRs is the information).
"""

import json
import os
import sys


def load(path):
    with open(path) as f:
        return {r["name"]: r for r in json.load(f)}


def main(argv):
    if len(argv) < 3:
        print(f"usage: {argv[0]} PREVIOUS.json CURRENT.json [--threshold PCT]")
        return 0
    threshold = 10.0
    if "--threshold" in argv:
        threshold = float(argv[argv.index("--threshold") + 1])

    summary_lines = []
    try:
        prev = load(argv[1])
    except (OSError, ValueError, KeyError, TypeError) as e:
        print(f"no usable previous record ({e}); nothing to diff")
        return 0
    try:
        cur = load(argv[2])
    except (OSError, ValueError, KeyError, TypeError) as e:
        print(f"::warning::bench-trend: current record unreadable ({e})")
        return 0

    summary_lines.append(f"### Bench trend (tokens/sec, warn at −{threshold:.0f}%)")
    summary_lines.append("")
    summary_lines.append("| benchmark | previous | current | Δ |")
    summary_lines.append("|---|---:|---:|---:|")
    regressions = []
    for name, c in cur.items():
        p = prev.get(name)
        if p is None or not p.get("tokens_per_sec"):
            summary_lines.append(f"| {name} | — | {c['tokens_per_sec']:.1f} | new |")
            continue
        delta = (c["tokens_per_sec"] / p["tokens_per_sec"] - 1.0) * 100.0
        mark = " ⚠️" if delta < -threshold else ""
        summary_lines.append(
            f"| {name} | {p['tokens_per_sec']:.1f} | "
            f"{c['tokens_per_sec']:.1f} | {delta:+.1f}%{mark} |"
        )
        if delta < -threshold:
            regressions.append((name, delta))
    dropped = [n for n in prev if n not in cur]
    if dropped:
        summary_lines.append("")
        summary_lines.append(
            f"{len(dropped)} benchmark(s) from the previous run are gone: "
            + ", ".join(sorted(dropped))
        )
    summary_lines.append("")
    if regressions:
        names = ", ".join(f"`{n}`" for n, _ in regressions)
        summary_lines.append(f"⚠️ {len(regressions)} regression(s) beyond {threshold:.0f}%: {names}")
    else:
        summary_lines.append(f"No regression beyond {threshold:.0f}%.")

    summary = "\n".join(summary_lines) + "\n"
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write(summary)
    print(summary)
    for name, delta in regressions:
        print(
            f"::warning::bench-trend: `{name}` tokens_per_sec "
            f"regressed {delta:+.1f}% vs previous run"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
