#!/usr/bin/env python3
"""Diff two BENCH_*.json artifacts and flag performance regressions.

Usage: bench_trend.py PREVIOUS.json CURRENT.json [--threshold PCT]

Compares every metric the records carry, not just throughput:

* ``tokens_per_sec`` — lower is worse (warn below -threshold%).
* ``ns_per_call``    — *higher* is worse (warn above +threshold%).
* ``acceptance_rate``— speculative-decoding draft acceptance; only
  present on ``serve spec`` records; lower is worse.

Writes a markdown table to $GITHUB_STEP_SUMMARY (stdout when unset) and
emits GitHub ``::warning::`` annotations for regressions beyond the
threshold (default 10%). Regressions never fail the job — smoke benches
on shared runners are noisy, the trajectory across PRs is the signal.
Records present in only one run are reported (``new`` / gone list) but
never fatal, so benchmarks can be added and retired freely.

Exit status: 0 when the previous file is absent (first run, expired
artifact) or the diff ran; **1 with a ``::error::`` annotation when
either file exists but is not a well-formed record array** — a silently
unparseable stream would otherwise disable the trend signal forever.
"""

import json
import os
import sys


def load(path):
    """Parse a bench-record array; raises ValueError on malformed input."""
    with open(path) as f:
        records = json.load(f)
    if not isinstance(records, list):
        raise ValueError(f"{path}: expected a JSON array of records")
    by_name = {}
    for i, r in enumerate(records):
        if not isinstance(r, dict) or "name" not in r:
            raise ValueError(f"{path}: record {i} has no 'name'")
        by_name[r["name"]] = r
    return by_name


def metric(rec, key):
    """A finite positive metric value, or None when absent/unusable."""
    v = rec.get(key)
    if isinstance(v, (int, float)) and v == v and v > 0:
        return float(v)
    return None


# (key, regression sign): -1 = lower is worse, +1 = higher is worse.
METRICS = [
    ("tokens_per_sec", -1),
    ("ns_per_call", +1),
    ("acceptance_rate", -1),
]

# Fault-containment counters serving records carry (all optional, all
# zero on a healthy run). They are not trended — a non-zero value in the
# *current* run means the bench served degraded and its perf numbers
# are suspect, which is worth a warning on its own.
ROBUSTNESS_KEYS = [
    "requests_failed",
    "shed_total",
    "degraded_ticks",
    "faults_injected",
    "events_dropped",
]


def main(argv):
    if len(argv) < 3:
        print(f"usage: {argv[0]} PREVIOUS.json CURRENT.json [--threshold PCT]")
        return 0
    threshold = 10.0
    if "--threshold" in argv:
        threshold = float(argv[argv.index("--threshold") + 1])

    if not os.path.exists(argv[1]):
        print(f"no previous record at {argv[1]}; nothing to diff")
        return 0
    try:
        prev = load(argv[1])
    except (OSError, ValueError) as e:
        print(f"::error::bench-trend: previous record malformed ({e})")
        return 1
    try:
        cur = load(argv[2])
    except (OSError, ValueError) as e:
        print(f"::error::bench-trend: current record unreadable ({e})")
        return 1

    summary_lines = [
        f"### Bench trend ({argv[2]}, warn at {threshold:.0f}%)",
        "",
        "| benchmark | metric | previous | current | Δ |",
        "|---|---|---:|---:|---:|",
    ]
    regressions = []
    degraded = []
    for name, c in cur.items():
        bad = {
            k: c[k]
            for k in ROBUSTNESS_KEYS
            if isinstance(c.get(k), (int, float)) and c[k] > 0
        }
        if bad:
            degraded.append((name, bad))
        p = prev.get(name)
        for key, sign in METRICS:
            cv = metric(c, key)
            if cv is None:
                continue  # metric not carried by this record
            pv = metric(p, key) if p is not None else None
            if pv is None:
                summary_lines.append(f"| {name} | {key} | — | {cv:.3g} | new |")
                continue
            delta = (cv / pv - 1.0) * 100.0
            regressed = sign * delta > threshold
            mark = " ⚠️" if regressed else ""
            summary_lines.append(
                f"| {name} | {key} | {pv:.3g} | {cv:.3g} | {delta:+.1f}%{mark} |"
            )
            if regressed:
                regressions.append((name, key, delta))
    dropped = [n for n in prev if n not in cur]
    if dropped:
        summary_lines.append("")
        summary_lines.append(
            f"{len(dropped)} benchmark(s) from the previous run are gone: "
            + ", ".join(sorted(dropped))
        )
    summary_lines.append("")
    if regressions:
        names = ", ".join(f"`{n}`/{k}" for n, k, _ in regressions)
        summary_lines.append(
            f"⚠️ {len(regressions)} regression(s) beyond {threshold:.0f}%: {names}"
        )
    else:
        summary_lines.append(f"No regression beyond {threshold:.0f}%.")
    if degraded:
        summary_lines.append("")
        for name, bad in degraded:
            counters = ", ".join(f"{k}={int(v)}" for k, v in sorted(bad.items()))
            summary_lines.append(
                f"⚠️ `{name}` served degraded ({counters}) — its numbers are suspect"
            )

    summary = "\n".join(summary_lines) + "\n"
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write(summary)
    print(summary)
    for name, key, delta in regressions:
        print(
            f"::warning::bench-trend: `{name}` {key} "
            f"regressed {delta:+.1f}% vs previous run"
        )
    for name, bad in degraded:
        counters = ", ".join(f"{k}={int(v)}" for k, v in sorted(bad.items()))
        print(f"::warning::bench-trend: `{name}` ran degraded ({counters})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
